//! The distributed engines (paper §5): the same three phases as the
//! shared-memory [`crate::engine::RoundDriver`] engines, sharded across
//! simulated machines with batched cross-shard messaging and first-class
//! network accounting. Two engines share one round body ([`DistCore`]):
//!
//! * [`DistRacEngine`] — exact reciprocal-NN merges (Theorem 1: equal to
//!   sequential HAC for every topology).
//! * [`DistApproxEngine`] — TeraHAC-style (1+ε)-good merges
//!   ([`crate::approx::good`]) over the same sharded state: bitwise
//!   identical to [`crate::approx::ApproxEngine`] for every
//!   `(machines, cores, ε)` topology, hence bitwise identical to
//!   [`DistRacEngine`] at ε = 0.
//!
//! ## Shard model
//!
//! Clusters are hash-partitioned over `machines` workers by id
//! ([`shard::shard_of`]); a merged cluster keeps its leader's id, so
//! ownership never migrates and every shard can locate any cluster's owner
//! without coordination. Each round runs the paper's phases as bulk
//! barriers, and every piece of state a shard needs from another shard is
//! staged as a [`network::Message`] and batched per ordered machine pair —
//! one RPC per non-empty pair per *communication step* (the merge phase
//! has two steps: the fetch/lookup exchange before computing unions, and
//! the patch push after applying them):
//!
//! 1. **Find merge pairs** — exact: NN-pointer queries/replies for
//!    clusters whose cached nearest neighbor lives on another shard.
//!    ε-good: the eligibility scan at edge `(a, b)` runs on the lower
//!    endpoint's shard and needs `b`'s cached NN edge, so remote NN
//!    *caches* (weight + pointer) are exchanged instead — only for edges
//!    that already pass `a`'s purely local half of the test; each shard then
//!    ships its candidate edges to the matching coordinator (machine 0),
//!    which broadcasts the selected maximal matching to every shard
//!    owning active clusters.
//! 2. **Update dissimilarities** — leaders with a remote partner fetch the
//!    partner's full neighbor map ([`network::Message::PartnerState`]);
//!    pair views of remote neighbors are queried; patches to remote
//!    non-merging neighbors ship as [`network::Message::EdgePatch`].
//! 3. **Update nearest neighbors** — purely local rescans (the patches of
//!    phase 2 already delivered everything a survivor needs).
//!
//! ## Accounting, not emulation
//!
//! This is a single-process *simulation*: the round computation reads the
//! authoritative global state directly (bit-identical to the shared-memory
//! engines, so Theorem 1 exactness — and the ε-band quality contract —
//! transfer verbatim and the dendrogram is independent of the
//! `(machines, cores)` topology), while every cross-shard batch is encoded
//! through the real wire codec and accounted at its exact encoded length.
//! Per round this produces `net_messages` (batched RPCs), `net_bytes`
//! (wire bytes), and `t_sim` — a critical-path time model (max per-machine
//! work per barrier phase, divided by cores for cluster-parallel phases,
//! plus latency and bandwidth terms) corresponding to paper Table 2's
//! resource columns. With `machines == 1` nothing ever crosses a shard
//! boundary and all three counters are exactly zero.
//!
//! ## Executed mode
//!
//! Every run can alternatively *execute* the same schedule for real:
//! [`DistRacEngine::with_exec`] / [`DistApproxEngine::with_exec`] switch
//! to [`exec`] — one OS thread per machine owning only its shard of the
//! arena, exchanging the same [`network::Message`] batches over channels
//! with injected link latency/jitter. At every sync point the driver
//! cuts a *chained* checkpoint through the versioned [`checkpoint`]
//! codec: a full blob every [`ExecOptions::checkpoint_full_every`] cuts,
//! dirty-row deltas between. Faults are a campaign
//! ([`ExecOptions::faults`] plus seeded [`ExecOptions::fault_rate`]);
//! a dead shard surfaces as a named [`MachineDown`] error and is
//! recovered either by BSP global rollback or by journaled per-shard
//! replay ([`RecoveryMode`]). The dendrogram, (1+ε) bounds trace, and
//! sync-point schedule are bitwise identical to the simulated run —
//! faulted or not, under either recovery mode
//! (`rust/tests/dist_executed.rs`); the executed mode reports measured
//! wall clock as [`RoundMetrics::t_exec`] (and recovery cost as
//! `t_recover`) where the simulation reports modeled `t_sim`. Traffic
//! accounting diverges where real execution must ship bytes the deferred
//! accounting does not charge (see the [`exec`] module docs).
//!
//! The serial round body here deliberately mirrors the shared-memory
//! [`crate::engine::RoundDriver`] phase for phase (selection logic is
//! literally shared via [`crate::approx::good`] and the reciprocal-NN
//! condition); it stays a separate loop because traffic/load accounting is
//! woven through every phase.
//!
//! ## Subgraph batching (`SyncMode::Batched`)
//!
//! The per-round engines pay one global synchronisation *every* round:
//! the ε-good find phase alone costs an NN-cache exchange, a candidate
//! gather at the coordinator, and a matching broadcast. TeraHAC
//! (arXiv:2308.03578) keeps those off the critical path by running many
//! good merges *inside* machine-local subgraphs between synchronisations.
//! [`SyncMode::Batched`] is that protocol over this crate's determinism
//! discipline:
//!
//! * Clusters are partitioned into `vshards` contiguous-id blocks
//!   ([`shard::vshard_of`]) — a *topology-independent* stand-in for
//!   TeraHAC's locality-maximising graph partition. Machines own whole
//!   blocks ([`shard::Placement::Blocked`]), so a block-local merge never
//!   needs another machine.
//! * Each round runs **one** eligibility sweep (the shared
//!   [`good::scan_row_candidates`] test) and partitions the candidates
//!   into *co-block* — every input to the test is machine-local, so the
//!   edge is mergeable with zero traffic — and *frontier*. Co-block
//!   selection is exactly what a shared-memory
//!   [`crate::engine::RoundDriver`] under the co-block
//!   [`crate::engine::EdgeScope`] mask would pick (blocks are
//!   endpoint-disjoint; `rust/tests/dist_batching.rs` pins the batched
//!   run's pre-sync merge prefix bitwise against a scoped driver run).
//!   Local rounds send **nothing**: phase-2 patches whose target lives
//!   on another machine are *deferred* — staged as
//!   [`Message::EdgePatch`] batches and flushed at the next sync point,
//!   which is when a real deployment would reconcile frontier replicas.
//! * Only when a local round finds no merge does the engine fall back to
//!   the full global exchange (the unbatched find phase, frontier edges
//!   included) — one **sync point**, counted in
//!   [`RoundMetrics::sync_points`] (1 per round for the per-round
//!   engines; the batched engine's headline is `sync_points < rounds`,
//!   demonstrated by `benches/dist_sync.rs` / `BENCH_dist_sync.json`).
//!
//! Correctness model: as everywhere in `dist`, the *computation* reads
//! the authoritative global state (so the dendrogram and quality trace
//! are bitwise invariant across `(machines, cpus)` — the partition
//! depends only on `(n, vshards)`), while the *traffic model* charges
//! what the deferred-flush protocol would ship, and only at sync
//! boundaries. A real deployment working from deferred (stale) frontier
//! state stays inside the quality contract by reducibility: patches never
//! lower a row's minimum, so a stale NN cache under-estimates the
//! visible minimum and only *tightens* the (1+ε) acceptance band. Every
//! recorded merge is still audited against the fresh visible minimum
//! (`rust/tests/dist_batching.rs`). At ε = 0 the batched schedule merges
//! only reciprocal-NN pairs, so it builds the same merge tree as the
//! exact engines whenever linkage values are distinct — but grouping
//! merges into different rounds associates the Lance–Williams folds
//! differently, so equality is dendrogram-wise (`same_clustering`), not
//! bitwise; the bitwise ε = 0 anchor is the *unbatched* engine's.

pub mod checkpoint;
pub mod exec;
pub mod network;
pub mod shard;

pub use exec::{ExecOptions, FaultSpec, MachineDown, RecoveryMode};
pub use network::{
    decode_batch, encode_batch, BatchRecord, JournalRecord, Message, NetReport, Network,
};
pub use shard::{partition, shard_of, vshard_of, Placement, ShardLoad, VShardScope};

use std::time::{Duration, Instant};

use rustc_hash::FxHashSet;

use crate::approx::good::{self, MergePair};
use crate::approx::quality::MergeBound;
use crate::approx::ApproxResult;
use crate::dendrogram::{Dendrogram, Merge};
use crate::graph::Graph;
use crate::linkage::{EdgeState, Linkage, Weight};
use crate::metrics::{RoundMetrics, RunMetrics};
use crate::rac::logic::{compute_union_map, scan_nn, PairView};
use crate::rac::{RacResult, NO_NN};
use crate::store::NeighborStore;
use crate::trace::{EventKind, Phase as TracePhase, TraceSink, COORD};

/// Simulated cost of one work unit (one neighbor entry / flag op).
const T_UNIT_NS: u128 = 200;
/// Simulated per-RPC latency (one batched cross-shard message).
const T_MSG_NS: u128 = 50_000;
/// Simulated per-byte cost (~1 GB/s effective cross-machine bandwidth).
const T_BYTE_NS: u128 = 1;

/// Deployment topology for the distributed engines (paper Fig 3's knobs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DistConfig {
    /// Number of shards / machines (≥ 1).
    pub machines: usize,
    /// Worker cores per machine; affects only the simulated critical-path
    /// time `t_sim`, never the result (≥ 1).
    pub cores_per_machine: usize,
}

impl DistConfig {
    /// Build a topology; both knobs are clamped to at least 1.
    pub fn new(machines: usize, cores_per_machine: usize) -> DistConfig {
        DistConfig {
            machines: machines.max(1),
            cores_per_machine: cores_per_machine.max(1),
        }
    }
}

impl Default for DistConfig {
    /// Matches the config-file defaults (`machines = 4`, `cpus = 2`).
    fn default() -> DistConfig {
        DistConfig::new(4, 2)
    }
}

/// Default virtual-shard count for [`SyncMode::Batched`] (the config-file
/// default when `sync_mode = "batched"` gives no `vshards`).
pub const DEFAULT_VSHARDS: u32 = 64;

/// Synchronisation schedule of the ε-good distributed engine.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum SyncMode {
    /// One global synchronisation per round (the PR-4 engine, unchanged —
    /// and the bitwise ε = 0 anchor to `dist_rac`).
    #[default]
    PerRound,
    /// TeraHAC-style subgraph batching: drain (1+ε)-good merges inside
    /// `vshards` contiguous-id blocks between synchronisations, syncing
    /// only when no block-local merge remains (module docs). `vshards` is
    /// part of the algorithm configuration — it changes the merge
    /// schedule (never the quality contract), while `(machines, cpus)`
    /// never change anything but traffic and `t_sim`.
    Batched { vshards: u32 },
}

type UnionEntry = crate::store::UnionRow;

/// Phase-1 strategy for the sharded round body — the distributed analogue
/// of the shared-memory [`crate::engine::PairSelector`] implementations
/// (serial, with traffic accounting; an enum rather than a trait because
/// the body is not generic-hot).
#[derive(Debug, Clone, Copy)]
enum DistSelector {
    /// Reciprocal nearest neighbors (exact).
    Rnn,
    /// (1+ε)-good merge matching, one global sync per round.
    Good { epsilon: f64 },
    /// (1+ε)-good matching with shard-local subgraph batching: co-block
    /// merges drain locally (no traffic, deferred patches), the global
    /// exchange runs only when a local round is dry (module docs).
    GoodBatched { epsilon: f64, vshards: u32 },
}

/// The state and round body shared by both distributed engines. The
/// phases, state layout, and per-round ordering are deliberately kept in
/// lockstep with [`crate::engine::RoundDriver`] — the exactness contract
/// is *bitwise* equality with the shared-memory engines' dendrograms
/// (`matches_shared_memory_engine_bitwise`,
/// `rust/tests/store_equivalence.rs`); change both or neither.
struct DistCore {
    linkage: Linkage,
    cfg: DistConfig,
    n: usize,
    active: Vec<bool>,
    /// Live cluster ids, ascending; compacted once per round.
    active_ids: Vec<u32>,
    size: Vec<u64>,
    nn: Vec<u32>,
    nn_weight: Vec<Weight>,
    /// Selected for a merge this round (cleared per round; see the
    /// phase-1 invariant in [`crate::engine::RoundState`]).
    matched: Vec<bool>,
    /// This round's merge partner (valid only while `matched`).
    partner: Vec<u32>,
    /// This round's merge weight (valid only while `matched`).
    pair_weight: Vec<Weight>,
    /// Flat arena-backed adjacency, shared representation with the
    /// shared-memory engines ([`crate::store`]).
    store: NeighborStore,
    /// Cluster → machine ownership for the traffic accounting (never
    /// affects results). `Mod` for the per-round engines; `Blocked` when
    /// batching, so virtual shards are machine-local.
    place: Placement,
    /// Cross-machine patches generated by local (non-sync) rounds, staged
    /// per ordered machine pair and flushed as real batches at the next
    /// sync point — "wire traffic only at sync boundaries".
    pending: Vec<Vec<Message>>,
    /// Hard cap on rounds (safety valve, as in the shared-memory engines).
    max_rounds: usize,
    /// Structured-event sink ([`crate::trace`]); disabled by default.
    /// Purely observational — never read by the round body.
    sink: TraceSink,
}

/// The engine name a selector runs under, for trace stamping.
pub(crate) fn engine_name(selector: DistSelector) -> &'static str {
    match selector {
        DistSelector::Rnn => "dist_rac",
        DistSelector::Good { .. } | DistSelector::GoodBatched { .. } => "dist_approx",
    }
}

impl DistCore {
    /// Shared guards + state init (same checks as
    /// [`crate::rac::RacEngine::new`]).
    fn new(g: &Graph, linkage: Linkage, cfg: DistConfig) -> DistCore {
        assert!(
            linkage.is_reducible(),
            "RAC is exact only for reducible linkages (Theorem 1)"
        );
        if !linkage.supports_sparse() {
            let n = g.n();
            assert!(
                g.m() == n * (n - 1) / 2,
                "{linkage:?} linkage requires a complete graph"
            );
        }
        let n = g.n();
        DistCore {
            linkage,
            cfg,
            n,
            active: vec![true; n],
            active_ids: (0..n as u32).collect(),
            size: vec![1; n],
            nn: vec![NO_NN; n],
            nn_weight: vec![Weight::INFINITY; n],
            matched: vec![false; n],
            partner: vec![NO_NN; n],
            pair_weight: vec![0.0; n],
            // Rows pre-sized exactly from the CSR degrees — one arena
            // allocation, no per-insert growth.
            store: NeighborStore::from_graph(g),
            place: Placement::Mod {
                machines: cfg.machines,
            },
            pending: vec![Vec::new(); cfg.machines * cfg.machines],
            max_rounds: 4 * n + 64,
            sink: TraceSink::disabled(),
        }
    }

    /// The machine owning `cluster` under this engine's placement.
    #[inline]
    fn machine_of(&self, cluster: u32) -> usize {
        self.place.machine_of(cluster)
    }

    /// True when no deferred cross-machine patches are staged. This is
    /// the checkpoint-cut invariant: blobs may only be cut at sync
    /// points where nothing is pending, or batched-mode recovery would
    /// silently drop staged patches ([`exec`] asserts it at every cut).
    fn pending_is_empty(&self) -> bool {
        self.pending.iter().all(Vec::is_empty)
    }

    /// Run the sharded round loop to completion.
    fn run_rounds(mut self, selector: DistSelector) -> (RacResult, NetReport, Vec<MergeBound>) {
        let t0 = Instant::now();
        // Coordinator-level trace buffer. The simulation has no real
        // per-machine threads, so wire traffic is emitted as one aggregate
        // `wire_send` per round — totals still equal the RunMetrics
        // counters, which is the analyzer's contract.
        let mut tb = self.sink.buf(engine_name(selector), COORD, 0);
        let run_start = tb.now();
        let m = self.cfg.machines;
        let cores = self.cfg.cores_per_machine as u64;
        let mut net = Network::new(m);
        let mut merges: Vec<Merge> = Vec::with_capacity(self.n.saturating_sub(1));
        let mut bounds: Vec<MergeBound> = Vec::with_capacity(self.n.saturating_sub(1));
        let mut metrics = RunMetrics::default();

        // Initial NN cache (local per shard: every shard scans only the
        // neighbor rows it owns).
        for c in 0..self.n {
            let (nn, w) = scan_nn(self.store.row(c as u32));
            self.nn[c] = nn;
            self.nn_weight[c] = w;
        }

        let mut n_active = self.n;
        for round in 0..self.max_rounds {
            let mut rm = RoundMetrics {
                round,
                clusters: n_active,
                ..Default::default()
            };
            let mut load = vec![ShardLoad::default(); m];
            tb.set_round(round);
            let round_start = tb.now();

            // ---- Phase 1: select this round's merge pairs ---------------
            // Every round of the per-round engines is one global
            // synchronisation; a batched round is local (and silent)
            // unless its shard-local merges are exhausted, in which case
            // it escalates to a sync point in place — flushing the
            // deferred cross-machine patches first, so the exchange
            // operates on reconciled replicas.
            let t = Instant::now();
            let find_start = tb.now();
            let (pairs, synced) = match selector {
                DistSelector::Rnn => {
                    rm.sync_points = 1;
                    (self.select_reciprocal(&mut net, &mut load), true)
                }
                DistSelector::Good { epsilon } => {
                    rm.sync_points = 1;
                    (self.select_good(epsilon, &mut net, &mut load, &mut rm), true)
                }
                DistSelector::GoodBatched { epsilon, vshards } => {
                    self.select_good_batched(epsilon, vshards, &mut net, &mut load, &mut rm)
                }
            };
            rm.t_find = t.elapsed();
            tb.span(find_start, EventKind::Phase(TracePhase::Find));
            for _ in 0..rm.sync_points {
                tb.instant(EventKind::SyncPoint);
            }
            rm.merges = pairs.len();

            if pairs.is_empty() {
                finish_round(&mut rm, &mut net, &load, cores);
                if rm.net_messages > 0 {
                    tb.instant(EventKind::WireSend {
                        dst: COORD,
                        step: 0,
                        msgs: rm.net_messages,
                        bytes: rm.net_bytes,
                    });
                }
                tb.span(round_start, EventKind::Round);
                metrics.rounds.push(rm);
                break;
            }

            // ---- Phase 2: update cluster dissimilarities ----------------
            let t = Instant::now();
            let merge_start = tb.now();
            let unions = self.compute_unions(&pairs, &mut net, &mut load, synced);
            for p in &pairs {
                merges.push(Merge {
                    a: p.leader,
                    b: p.partner,
                    weight: p.weight,
                });
                bounds.push(MergeBound {
                    weight: p.weight,
                    visible_min: self.nn_weight[p.leader as usize]
                        .min(self.nn_weight[p.partner as usize]),
                });
            }
            self.apply_unions(unions, &mut net, synced);
            n_active -= rm.merges;
            self.active_ids.retain(|&c| self.active[c as usize]);
            rm.t_merge = t.elapsed();
            tb.span(merge_start, EventKind::Phase(TracePhase::Merge));

            // ---- Phase 3: update nearest neighbors (local) --------------
            let t = Instant::now();
            let update_start = tb.now();
            let updates: Vec<(u32, u32, Weight, usize)> = self
                .active_ids
                .iter()
                .filter_map(|&c| {
                    let c = c as usize;
                    let needs_rescan = self.matched[c]
                        || (self.nn[c] != NO_NN && self.matched[self.nn[c] as usize]);
                    needs_rescan.then(|| {
                        let row = self.store.row(c as u32);
                        let (nn, w) = scan_nn(row);
                        (c as u32, nn, w, row.live_len())
                    })
                })
                .collect();
            rm.nn_updates = updates.len();
            for (c, nn, w, scanned) in updates {
                self.nn[c as usize] = nn;
                self.nn_weight[c as usize] = w;
                rm.nn_scan_entries += scanned;
                load[self.machine_of(c)].nn_scan_work += scanned as u64;
            }
            // Clear this round's selection (phase-1 invariant; retired
            // partners' stale flags are unreachable).
            for p in &pairs {
                self.matched[p.leader as usize] = false;
                self.matched[p.partner as usize] = false;
            }
            rm.t_update_nn = t.elapsed();
            tb.span(update_start, EventKind::Phase(TracePhase::UpdateNn));

            if n_active <= 1 {
                // A local round can finish the run outright only when one
                // machine holds every remaining cluster (cross-machine
                // merges happen at sync points, which flush) — so nothing
                // deferred can be pending here.
                debug_assert!(
                    self.pending_is_empty(),
                    "run finished with unflushed deferred patches"
                );
            }
            finish_round(&mut rm, &mut net, &load, cores);
            if rm.net_messages > 0 {
                tb.instant(EventKind::WireSend {
                    dst: COORD,
                    step: 0,
                    msgs: rm.net_messages,
                    bytes: rm.net_bytes,
                });
            }
            tb.span(round_start, EventKind::Round);
            metrics.rounds.push(rm);

            if n_active <= 1 {
                break;
            }
        }

        metrics.total_time = t0.elapsed();
        tb.span(run_start, EventKind::Run);
        self.sink.absorb(tb);
        (
            RacResult {
                dendrogram: Dendrogram::new(self.n, merges),
                metrics,
            },
            net.into_report(),
            bounds,
        )
    }

    /// Exact phase 1: exchange remote NN pointers, then select the
    /// reciprocal pairs (`nn(nn(c)) == c`) in ascending-id order.
    fn select_reciprocal(&mut self, net: &mut Network, load: &mut [ShardLoad]) -> Vec<MergePair> {
        self.exchange_nn_pointers(net, load);
        let mut pairs = Vec::new();
        for &c in &self.active_ids {
            let ci = c as usize;
            if self.nn[ci] != NO_NN && self.nn[self.nn[ci] as usize] == c {
                self.matched[ci] = true;
                self.partner[ci] = self.nn[ci];
                self.pair_weight[ci] = self.nn_weight[ci];
                if c < self.nn[ci] {
                    pairs.push(MergePair {
                        leader: c,
                        partner: self.nn[ci],
                        weight: self.nn_weight[ci],
                    });
                }
            }
        }
        pairs
    }

    /// ε-good phase 1 over the sharded state: exchange remote NN caches,
    /// scan owned rows for edges both endpoints accept
    /// ([`good::accepts`]), then run the shared coordinator matching
    /// ([`Self::coordinate_matching`]).
    fn select_good(
        &mut self,
        epsilon: f64,
        net: &mut Network,
        load: &mut [ShardLoad],
        rm: &mut RoundMetrics,
    ) -> Vec<MergePair> {
        self.exchange_nn_caches(epsilon, net, load);

        // Local scans, in ascending id order, through the single shared
        // eligibility test ([`good::scan_row_candidates`] — the same
        // function the shared-memory selector runs, so the candidate set
        // is identical).
        let mut candidates: Vec<good::Candidate> = Vec::new();
        for &a in &self.active_ids {
            let (row_cands, scanned) = good::scan_row_candidates(
                self.store.row(a),
                a,
                epsilon,
                &self.nn_weight,
                &self.nn,
            );
            rm.eligibility_scan_entries += scanned;
            candidates.extend(row_cands.into_iter().map(|(w, b)| (w, a, b)));
        }
        self.coordinate_matching(candidates, net, load)
    }

    /// Batched phase 1: **one** eligibility sweep per round, partitioned
    /// into co-block candidates (decidable and mergeable with zero
    /// traffic — every input to the test lives on the block's machine)
    /// and frontier candidates (their remote halves need the global
    /// exchange; the simulation evaluates them against the authoritative
    /// state as usual, and they are *used* only at sync rounds, where the
    /// cache-exchange traffic is staged). Local merges win the round when
    /// any exist: blocks are endpoint-disjoint, so pooling the co-block
    /// candidates through the shared [`good::select_matching`] yields
    /// exactly the union of the per-block matchings a fleet of scoped
    /// per-shard drivers would select (`rust/tests/dist_batching.rs` pins
    /// the equivalence). At the local fixed point the round escalates to
    /// a sync in place: deferred patches flush, the cache queries are
    /// staged (the sweep itself is already charged — no double count),
    /// and the full candidate set — exactly the frontier, the local set
    /// being empty — goes through the same coordinator matching as the
    /// per-round engine.
    fn select_good_batched(
        &mut self,
        epsilon: f64,
        vshards: u32,
        net: &mut Network,
        load: &mut [ShardLoad],
        rm: &mut RoundMetrics,
    ) -> (Vec<MergePair>, bool) {
        let n = self.n;
        let mut local: Vec<good::Candidate> = Vec::new();
        let mut frontier: Vec<good::Candidate> = Vec::new();
        for &a in &self.active_ids {
            load[self.machine_of(a)].find_work += self.store.row(a).live_len() as u64;
            let (row_cands, scanned) = good::scan_row_candidates(
                self.store.row(a),
                a,
                epsilon,
                &self.nn_weight,
                &self.nn,
            );
            rm.eligibility_scan_entries += scanned;
            let va = vshard_of(a, n, vshards);
            for (w, b) in row_cands {
                if vshard_of(b, n, vshards) == va {
                    local.push((w, a, b));
                } else {
                    frontier.push((w, a, b));
                }
            }
        }
        if !local.is_empty() {
            // Each block's matching runs on its own machine.
            for &(_, a, _) in &local {
                load[self.machine_of(a)].find_work += 1;
            }
            let pairs = good::select_matching(local, &mut self.matched);
            for p in &pairs {
                debug_assert_eq!(
                    self.machine_of(p.leader),
                    self.machine_of(p.partner),
                    "local merges must be machine-local"
                );
                self.partner[p.leader as usize] = p.partner;
                self.partner[p.partner as usize] = p.leader;
                self.pair_weight[p.leader as usize] = p.weight;
                self.pair_weight[p.partner as usize] = p.weight;
            }
            (pairs, false)
        } else {
            rm.sync_points = 1;
            self.flush_pending(net);
            self.stage_nn_cache_queries(epsilon, net);
            (self.coordinate_matching(frontier, net, load), true)
        }
    }

    /// Coordinator step shared by the per-round and batched sync paths:
    /// ship each machine's candidates to the coordinator (machine 0),
    /// select the maximal conflict-free matching
    /// ([`good::select_matching`] — the same deterministic function the
    /// shared-memory [`crate::engine::GoodSelector`] runs, so the
    /// selected pairs are identical), record the pair bookkeeping, and
    /// broadcast the selection to every shard owning active clusters
    /// (idle shards have nothing to merge or patch).
    fn coordinate_matching(
        &mut self,
        candidates: Vec<good::Candidate>,
        net: &mut Network,
        load: &mut [ShardLoad],
    ) -> Vec<MergePair> {
        let m = net.machines();
        // Ship each shard's candidates to the coordinator...
        if m > 1 {
            let mut per_shard: Vec<Vec<(Weight, u32, u32)>> = vec![Vec::new(); m];
            for &(w, a, b) in &candidates {
                per_shard[self.machine_of(a)].push((w, a, b));
            }
            for (s, edges) in per_shard.into_iter().enumerate() {
                if s != 0 && !edges.is_empty() {
                    net.send(s, 0, &[Message::CandidateBatch { edges }]);
                }
            }
        }
        // ...who pays the matching cost...
        load[0].find_work += candidates.len() as u64;
        let pairs = good::select_matching(candidates, &mut self.matched);
        for p in &pairs {
            self.partner[p.leader as usize] = p.partner;
            self.partner[p.partner as usize] = p.leader;
            self.pair_weight[p.leader as usize] = p.weight;
            self.pair_weight[p.partner as usize] = p.weight;
        }
        // ...and broadcasts the selection.
        if m > 1 && !pairs.is_empty() {
            let sel: Vec<(u32, u32, Weight)> = pairs
                .iter()
                .map(|p| (p.leader, p.partner, p.weight))
                .collect();
            let mut has_active = vec![false; m];
            for &c in &self.active_ids {
                has_active[self.machine_of(c)] = true;
            }
            for (s, owns) in has_active.iter().enumerate() {
                if s != 0 && *owns {
                    net.send(0, s, &[Message::MatchingBroadcast { pairs: sel.clone() }]);
                }
            }
        }
        pairs
    }

    /// Ship the cross-machine patches deferred by local rounds as real
    /// batches, charged to the current round (a sync boundary).
    fn flush_pending(&mut self, net: &mut Network) {
        let m = net.machines();
        for src in 0..m {
            for dst in 0..m {
                let batch = std::mem::take(&mut self.pending[src * m + dst]);
                if !batch.is_empty() {
                    net.send(src, dst, &batch);
                }
            }
        }
    }

    /// Exact phase-1 traffic: every shard must evaluate `nn(nn(c)) == c`
    /// for its clusters, which needs the NN pointer of each *remote*
    /// `nn(c)`. Queries are deduplicated per (asking shard, target
    /// cluster) and batched per machine pair, replies likewise.
    fn exchange_nn_pointers(&self, net: &mut Network, load: &mut [ShardLoad]) {
        let m = net.machines();
        for &c in &self.active_ids {
            load[self.machine_of(c)].find_work += 1;
        }
        if m == 1 {
            return;
        }
        let mut queries: Vec<Vec<Message>> = vec![Vec::new(); m * m];
        let mut seen: FxHashSet<(usize, u32)> = FxHashSet::default();
        for &c in &self.active_ids {
            let v = self.nn[c as usize];
            if v == NO_NN {
                continue;
            }
            let (src, dst) = (self.machine_of(c), self.machine_of(v));
            if src != dst && seen.insert((src, v)) {
                queries[src * m + dst].push(Message::NnQuery { cluster: v });
            }
        }
        for src in 0..m {
            for dst in 0..m {
                if src == dst {
                    continue;
                }
                let batch = std::mem::take(&mut queries[src * m + dst]);
                if batch.is_empty() {
                    continue;
                }
                let replies: Vec<Message> = batch
                    .iter()
                    .map(|q| match q {
                        Message::NnQuery { cluster } => Message::NnReply {
                            cluster: *cluster,
                            nn: self.nn[*cluster as usize],
                        },
                        _ => unreachable!("phase-1 batches hold only NN queries"),
                    })
                    .collect();
                net.send(src, dst, &batch);
                net.send(dst, src, &replies);
            }
        }
    }

    /// ε-good phase-1 traffic: the eligibility test at edge `(a, b)` runs
    /// on `a`'s shard (a < b) and needs `b`'s cached NN *edge* — weight
    /// and pointer, not just the pointer — so remote caches are queried
    /// per (asking shard, target), deduplicated and batched per machine
    /// pair. `a`'s half of the test ([`good::accepts`] against `a`'s own
    /// cache) is purely local, so a query is staged only for edges that
    /// pass it — a real protocol never ships the rest, and filtering here
    /// changes no selection result (the scan reads the authoritative
    /// state directly), only tightens the traffic model. Scan work is
    /// charged to the scanning shard.
    fn exchange_nn_caches(&self, epsilon: f64, net: &mut Network, load: &mut [ShardLoad]) {
        for &a in &self.active_ids {
            load[self.machine_of(a)].find_work += self.store.row(a).live_len() as u64;
        }
        self.stage_nn_cache_queries(epsilon, net);
    }

    /// The staging half of [`Self::exchange_nn_caches`]: queries/replies
    /// only, no scan-work charge — the batched sync path calls this after
    /// its (already charged) partitioned sweep.
    fn stage_nn_cache_queries(&self, epsilon: f64, net: &mut Network) {
        let m = net.machines();
        if m == 1 {
            return;
        }
        let mut queries: Vec<Vec<Message>> = vec![Vec::new(); m * m];
        let mut seen: FxHashSet<(usize, u32)> = FxHashSet::default();
        for &a in &self.active_ids {
            let sa = self.machine_of(a);
            for (b, e) in self.store.row(a).iter() {
                if b > a
                    && good::accepts(
                        e.weight,
                        b,
                        epsilon,
                        self.nn_weight[a as usize],
                        self.nn[a as usize],
                    )
                {
                    let sb = self.machine_of(b);
                    if sb != sa && seen.insert((sa, b)) {
                        queries[sa * m + sb].push(Message::NnCacheQuery { cluster: b });
                    }
                }
            }
        }
        for src in 0..m {
            for dst in 0..m {
                if src == dst {
                    continue;
                }
                let batch = std::mem::take(&mut queries[src * m + dst]);
                if batch.is_empty() {
                    continue;
                }
                let replies: Vec<Message> = batch
                    .iter()
                    .map(|q| match q {
                        Message::NnCacheQuery { cluster } => Message::NnCacheReply {
                            cluster: *cluster,
                            nn: self.nn[*cluster as usize],
                            weight: self.nn_weight[*cluster as usize],
                        },
                        _ => unreachable!("cache batches hold only NN-cache queries"),
                    })
                    .collect();
                net.send(src, dst, &batch);
                net.send(dst, src, &replies);
            }
        }
    }

    /// Phase-2 compute: every leader builds the union map of `L ∪ P`
    /// exactly as the shared-memory driver does (same fold, same order),
    /// while the traffic a real deployment would need — partner-state
    /// fetches, remote pair-view lookups — is staged and delivered as
    /// per-pair batches.
    ///
    /// In a batched engine's local round (`synced == false`) nothing is
    /// staged: leaders and partners share a machine by construction, and
    /// a real deployment's local phase reads its own (frontier-stale)
    /// replicas instead of querying remote pair views — the sync point is
    /// where reconciliation traffic flows (module docs).
    fn compute_unions(
        &self,
        pairs: &[MergePair],
        net: &mut Network,
        load: &mut [ShardLoad],
        synced: bool,
    ) -> Vec<UnionEntry> {
        let m = net.machines();
        let mut stage: Vec<Vec<Message>> = vec![Vec::new(); m * m];
        let mut viewed: FxHashSet<(usize, u32)> = FxHashSet::default();
        let mut out = Vec::with_capacity(pairs.len());
        for pr in pairs {
            let (l, p) = (pr.leader, pr.partner);
            let (sl, sp) = (self.machine_of(l), self.machine_of(p));
            load[sl].merge_work +=
                (self.store.row(l).live_len() + self.store.row(p).live_len()) as u64;
            if synced {
                if sl != sp {
                    stage[sl * m + sp].push(Message::PartnerFetch { partner: p });
                    stage[sp * m + sl].push(Message::PartnerState {
                        partner: p,
                        size: self.size[p as usize],
                        entries: self
                            .store
                            .row(p)
                            .iter()
                            .map(|(t, e)| (t, e.weight, e.count))
                            .collect(),
                    });
                }
                // Pair views the union computation will request: every
                // neighbor of L or P, plus the partner of any merging
                // neighbor (the canonicalisation step views both members).
                for (x, _) in self.store.row(l).iter().chain(self.store.row(p).iter()) {
                    if x == l || x == p {
                        continue;
                    }
                    self.stage_view(x, sl, m, &mut viewed, &mut stage);
                    if self.matched[x as usize] {
                        self.stage_view(self.partner[x as usize], sl, m, &mut viewed, &mut stage);
                    }
                }
            }
            out.push((l, self.union_map(l, p)));
        }
        for src in 0..m {
            for dst in 0..m {
                if src != dst {
                    net.send(src, dst, &stage[src * m + dst]);
                }
            }
        }
        out
    }

    /// Stage a pair-view query/reply pair for `x` if its owner is not the
    /// asking shard `sl` (deduplicated per shard per round).
    fn stage_view(
        &self,
        x: u32,
        sl: usize,
        m: usize,
        viewed: &mut FxHashSet<(usize, u32)>,
        stage: &mut [Vec<Message>],
    ) {
        let sx = self.machine_of(x);
        if sx == sl || !viewed.insert((sl, x)) {
            return;
        }
        stage[sl * m + sx].push(Message::PairViewQuery { cluster: x });
        stage[sx * m + sl].push(Message::PairViewReply {
            cluster: x,
            merging: self.matched[x as usize],
            partner: self.partner[x as usize],
            size: self.size[x as usize],
            pair_weight: self.pair_weight[x as usize],
        });
    }

    /// Phase-2 apply, in ascending leader order (identical to the
    /// shared-memory driver): install unions, retire partners, patch
    /// non-merging neighbors — shipping each patch whose target lives on
    /// another machine. Local rounds (`synced == false`) *defer* those
    /// cross-machine patches into [`Self::flush_pending`]'s staging
    /// instead of sending: the wire carries them at the next sync
    /// boundary, which is when the modeled protocol reconciles frontier
    /// replicas (the simulation applies them to the authoritative store
    /// immediately either way — placement never affects results).
    fn apply_unions(&mut self, unions: Vec<UnionEntry>, net: &mut Network, synced: bool) {
        let m = net.machines();
        let mut patches: Vec<Vec<Message>> = vec![Vec::new(); m * m];
        for (l, map) in unions {
            let p = self.partner[l as usize];
            let sl = self.machine_of(l);
            for &(t_id, e) in &map {
                if !self.matched[t_id as usize] {
                    self.store.patch(t_id, l, p, e);
                    let st = self.machine_of(t_id);
                    if st != sl {
                        patches[sl * m + st].push(Message::EdgePatch {
                            target: t_id,
                            leader: l,
                            retired: p,
                            weight: e.weight,
                            count: e.count,
                        });
                    }
                }
            }
            self.size[l as usize] += self.size[p as usize];
            self.store.install_row(l, &map);
            self.store.clear_row(p);
            self.active[p as usize] = false;
        }
        // Same per-round compaction point as the shared-memory engines, so
        // the stores' live/dead trajectories stay in lockstep.
        self.store.maybe_compact();
        for src in 0..m {
            for dst in 0..m {
                if src == dst {
                    continue;
                }
                let batch = std::mem::take(&mut patches[src * m + dst]);
                if synced {
                    net.send(src, dst, &batch);
                } else {
                    self.pending[src * m + dst].extend(batch);
                }
            }
        }
    }

    /// Neighbor map of the union `L ∪ P` — delegates to the engine-shared
    /// [`compute_union_map`] with the same arguments as the shared-memory
    /// driver, so the arithmetic (and its floating-point rounding) is
    /// bitwise identical.
    fn union_map(&self, l: u32, p: u32) -> Vec<(u32, EdgeState)> {
        compute_union_map(
            self.linkage,
            l,
            p,
            self.pair_weight[l as usize],
            self.size[l as usize],
            self.size[p as usize],
            self.store.row(l),
            self.store.row(p),
            |x| PairView {
                merging: self.matched[x as usize],
                partner: self.partner[x as usize],
                size: self.size[x as usize],
                pair_weight: self.pair_weight[x as usize],
            },
        )
    }
}

/// Distributed RAC engine. Exact: for any topology the dendrogram is
/// bitwise identical to [`crate::rac::RacEngine`]'s and therefore (for
/// reducible linkages) to sequential HAC — Theorem 1.
pub struct DistRacEngine {
    core: DistCore,
    exec: Option<ExecOptions>,
}

impl DistRacEngine {
    /// Build an engine over a dissimilarity graph.
    ///
    /// # Panics
    /// If the linkage is not reducible (Theorem 1 does not apply), or if a
    /// complete-graph-only linkage is given a sparse graph — the same
    /// guards as the shared-memory engine.
    pub fn new(g: &Graph, linkage: Linkage, cfg: DistConfig) -> DistRacEngine {
        DistRacEngine {
            core: DistCore::new(g, linkage, cfg),
            exec: None,
        }
    }

    /// Override the round safety cap.
    pub fn with_max_rounds(mut self, max_rounds: usize) -> DistRacEngine {
        self.core.max_rounds = max_rounds;
        self
    }

    /// Run *executed* instead of simulated: thread-per-machine shards,
    /// channel-backed wire, sync-point checkpoints, optional fault
    /// injection (module docs, [`exec`]). Bitwise-equal results;
    /// measured `t_exec` instead of modeled `t_sim`.
    pub fn with_exec(mut self, opts: ExecOptions) -> DistRacEngine {
        self.exec = Some(opts);
        self
    }

    /// Stream structured trace events into `sink` (see [`crate::trace`]).
    /// Works in both simulated and executed mode; purely observational.
    pub fn with_trace(mut self, sink: &TraceSink) -> DistRacEngine {
        self.core.sink = sink.clone();
        self
    }

    /// Run to completion; returns the dendrogram and per-round metrics
    /// (including the simulated network columns).
    pub fn run(self) -> RacResult {
        self.run_detailed().0
    }

    /// Like [`run`](Self::run), but also returns the full cross-shard
    /// traffic log for accounting-invariant tests and topology studies.
    pub fn run_detailed(self) -> (RacResult, NetReport) {
        let (result, report, _bounds) = match self.exec {
            Some(opts) => exec::run_executed(self.core, DistSelector::Rnn, &opts),
            None => self.core.run_rounds(DistSelector::Rnn),
        };
        (result, report)
    }
}

/// Distributed (1+ε)-approximate engine (`dist_approx`): ε-good merges
/// ([`crate::approx::good`]) over the sharded state. In the default
/// [`SyncMode::PerRound`], for every `(machines, cores)` topology the
/// dendrogram is bitwise identical to [`crate::approx::ApproxEngine`] at
/// the same ε — so at ε = 0 it is bitwise identical to [`DistRacEngine`]
/// and (Theorem 1) sequential HAC. [`SyncMode::Batched`] trades that
/// bitwise anchor for TeraHAC-style shard-local merge batching
/// (`sync_points < rounds`; module docs): the dendrogram is still
/// bitwise invariant across topologies, every merge still audits within
/// (1+ε) of the visible minimum, and at ε = 0 it builds the exact merge
/// tree whenever linkage values are distinct.
pub struct DistApproxEngine {
    core: DistCore,
    epsilon: f64,
    sync: SyncMode,
    exec: Option<ExecOptions>,
}

impl DistApproxEngine {
    /// Build an engine over a dissimilarity graph (sync mode:
    /// [`SyncMode::PerRound`]).
    ///
    /// # Panics
    /// The same guards as [`crate::approx::ApproxEngine::new`]: `epsilon`
    /// must be finite and `>= 0`, the linkage reducible, and
    /// complete-graph-only linkages need a complete graph.
    pub fn new(g: &Graph, linkage: Linkage, cfg: DistConfig, epsilon: f64) -> DistApproxEngine {
        assert!(
            epsilon >= 0.0 && epsilon.is_finite(),
            "epsilon must be finite and >= 0, got {epsilon}"
        );
        DistApproxEngine {
            core: DistCore::new(g, linkage, cfg),
            epsilon,
            sync: SyncMode::PerRound,
            exec: None,
        }
    }

    /// Run *executed* instead of simulated: thread-per-machine shards,
    /// channel-backed wire, sync-point checkpoints, optional fault
    /// injection (module docs, [`exec`]). Bitwise-equal results;
    /// measured `t_exec` instead of modeled `t_sim`.
    pub fn with_exec(mut self, opts: ExecOptions) -> DistApproxEngine {
        self.exec = Some(opts);
        self
    }

    /// Stream structured trace events into `sink` (see [`crate::trace`]).
    /// Works in both simulated and executed mode; purely observational.
    pub fn with_trace(mut self, sink: &TraceSink) -> DistApproxEngine {
        self.core.sink = sink.clone();
        self
    }

    /// Override the round safety cap.
    pub fn with_max_rounds(mut self, max_rounds: usize) -> DistApproxEngine {
        self.core.max_rounds = max_rounds;
        self
    }

    /// Select the synchronisation schedule. Batching switches machine
    /// placement to whole virtual shards ([`Placement::Blocked`]) so the
    /// local phase is machine-local by construction.
    ///
    /// # Panics
    /// If a batched mode passes `vshards == 0`.
    pub fn with_sync_mode(mut self, sync: SyncMode) -> DistApproxEngine {
        if let SyncMode::Batched { vshards } = sync {
            assert!(vshards >= 1, "vshards must be >= 1, got {vshards}");
            self.core.place = Placement::Blocked {
                n: self.core.n,
                vshards,
                machines: self.core.cfg.machines,
            };
        } else {
            self.core.place = Placement::Mod {
                machines: self.core.cfg.machines,
            };
        }
        self.sync = sync;
        self
    }

    /// Run to completion; returns the dendrogram, metrics (including the
    /// simulated network columns), and the per-merge quality trace.
    pub fn run(self) -> ApproxResult {
        self.run_detailed().0
    }

    /// Like [`run`](Self::run), but also returns the full cross-shard
    /// traffic log.
    pub fn run_detailed(self) -> (ApproxResult, NetReport) {
        let epsilon = self.epsilon;
        let selector = match self.sync {
            SyncMode::PerRound => DistSelector::Good { epsilon },
            SyncMode::Batched { vshards } => DistSelector::GoodBatched { epsilon, vshards },
        };
        let (result, report, bounds) = match self.exec {
            Some(opts) => exec::run_executed(self.core, selector, &opts),
            None => self.core.run_rounds(selector),
        };
        (
            ApproxResult {
                dendrogram: result.dendrogram,
                metrics: result.metrics,
                bounds,
            },
            report,
        )
    }
}

/// Close a round: pull the network counters into the metrics and evaluate
/// the critical-path time model. Each phase is a barrier, so its simulated
/// duration is the maximum per-machine work, divided by the cores each
/// machine parallelises cluster-level work across; the network contributes
/// a latency term per batched RPC and a bandwidth term per wire byte.
fn finish_round(rm: &mut RoundMetrics, net: &mut Network, load: &[ShardLoad], cores: u64) {
    let (msgs, bytes) = net.end_round();
    rm.net_messages = msgs;
    rm.net_bytes = bytes;
    let phase_max = |f: fn(&ShardLoad) -> u64| load.iter().map(f).max().unwrap_or(0);
    let compute = phase_max(|s| s.find_work).div_ceil(cores)
        + phase_max(|s| s.merge_work).div_ceil(cores)
        + phase_max(|s| s.nn_scan_work).div_ceil(cores);
    let ns = compute as u128 * T_UNIT_NS + msgs as u128 * T_MSG_NS + bytes as u128 * T_BYTE_NS;
    rm.t_sim = Duration::from_nanos(ns.min(u64::MAX as u128) as u64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{quality, ApproxEngine};
    use crate::data;
    use crate::hac::naive_hac;

    #[test]
    fn default_config_is_clamped_and_copy() {
        let cfg = DistConfig::new(0, 0);
        assert_eq!(cfg, DistConfig::new(1, 1));
        let d = DistConfig::default();
        assert_eq!((d.machines, d.cores_per_machine), (4, 2));
        let copy = d; // Copy, not move
        assert_eq!(copy, d);
    }

    #[test]
    fn two_points_across_two_shards() {
        let g = Graph::from_edges(2, [(0, 1, 3.5)]);
        let (r, report) = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(2, 1))
            .run_detailed();
        assert_eq!(r.dendrogram.merges().len(), 1);
        assert_eq!(r.dendrogram.merges()[0].weight, 3.5);
        // Node 1's NN pointer lives on shard 0 and vice versa: the find
        // phase must have exchanged pointers.
        assert!(r.metrics.total_net_messages() > 0);
        assert!(report.batches.iter().all(|b| b.src != b.dst));
    }

    #[test]
    fn more_machines_than_clusters() {
        // Shards 5..15 own nothing; the engine must not stumble on them.
        let g = data::grid1d_graph(5, 1);
        let r = DistRacEngine::new(&g, Linkage::Single, DistConfig::new(16, 4)).run();
        assert_eq!(r.dendrogram.merges().len(), 4);
        let hac = naive_hac(&g, Linkage::Single);
        assert!(hac.same_clustering(&r.dendrogram, 1e-12));
    }

    #[test]
    fn empty_and_singleton() {
        let r = DistRacEngine::new(&Graph::from_edges(0, []), Linkage::Average, DistConfig::new(3, 1))
            .run();
        assert!(r.dendrogram.merges().is_empty());
        assert_eq!(r.metrics.total_net_bytes(), 0);
        let r = DistRacEngine::new(&Graph::from_edges(1, []), Linkage::Average, DistConfig::new(3, 1))
            .run();
        assert!(r.dendrogram.merges().is_empty());
    }

    #[test]
    fn single_machine_is_silent_and_exact() {
        let g = data::grid1d_graph(64, 7);
        let (r, report) =
            DistRacEngine::new(&g, Linkage::Average, DistConfig::new(1, 8)).run_detailed();
        assert_eq!(r.metrics.total_net_messages(), 0);
        assert_eq!(r.metrics.total_net_bytes(), 0);
        assert!(report.batches.is_empty());
        assert!(r.metrics.total_sim_time().as_nanos() > 0);
        let hac = naive_hac(&g, Linkage::Average);
        assert!(hac.same_clustering(&r.dendrogram, 1e-12));
    }

    #[test]
    fn matches_shared_memory_engine_bitwise() {
        let g = data::grid1d_graph(200, 17);
        for l in Linkage::SPARSE_REDUCIBLE {
            let shared = crate::rac::RacEngine::new(&g, l).run();
            let dist = DistRacEngine::new(&g, l, DistConfig::new(5, 3)).run();
            let a: Vec<_> = shared
                .dendrogram
                .merges()
                .iter()
                .map(|m| (m.a, m.b, m.weight.to_bits()))
                .collect();
            let b: Vec<_> = dist
                .dendrogram
                .merges()
                .iter()
                .map(|m| (m.a, m.b, m.weight.to_bits()))
                .collect();
            assert_eq!(a, b, "{l:?}: dist must mirror the shared engine bitwise");
        }
    }

    #[test]
    fn max_rounds_zero_produces_empty_run() {
        let g = data::grid1d_graph(10, 1);
        let r = DistRacEngine::new(&g, Linkage::Single, DistConfig::default())
            .with_max_rounds(0)
            .run();
        assert!(r.dendrogram.merges().is_empty());
        assert!(r.metrics.rounds.is_empty());
    }

    #[test]
    #[should_panic(expected = "reducible")]
    fn rejects_centroid() {
        let g = data::stable_hierarchy(2, 4.0, 0);
        DistRacEngine::new(&g, Linkage::Centroid, DistConfig::default());
    }

    #[test]
    fn checkpoint_cut_invariant_tracks_staged_patches() {
        let g = data::grid1d_graph(8, 1);
        let mut core = DistCore::new(&g, Linkage::Average, DistConfig::new(2, 1));
        assert!(core.pending_is_empty(), "boot state has nothing staged");
        core.pending[1].push(Message::NnQuery { cluster: 3 });
        assert!(
            !core.pending_is_empty(),
            "a staged deferred batch must be visible to the cut invariant"
        );
        let mut net = Network::new(2);
        core.flush_pending(&mut net);
        assert!(
            core.pending_is_empty(),
            "a sync-point flush must restore the cut invariant"
        );
    }

    #[test]
    fn sim_time_scales_down_with_cores() {
        let g = data::grid1d_graph(400, 3);
        let slow = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(4, 1)).run();
        let fast = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(4, 8)).run();
        assert!(slow.dendrogram.same_clustering(&fast.dendrogram, 1e-15));
        assert!(
            fast.metrics.total_sim_time() < slow.metrics.total_sim_time(),
            "more cores per machine must shorten the simulated critical path"
        );
    }

    // ------------------------------------------------------------------
    // dist_approx
    // ------------------------------------------------------------------

    #[test]
    fn dist_approx_matches_shared_memory_approx_bitwise() {
        let g = data::grid1d_graph(200, 17);
        for eps in [0.0, 0.1, 1.0] {
            let shared = ApproxEngine::new(&g, Linkage::Average, eps).run();
            let dist =
                DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(5, 3), eps).run();
            assert_eq!(
                shared.dendrogram.bitwise_merges(),
                dist.dendrogram.bitwise_merges(),
                "eps={eps}"
            );
            // The quality trace rides along unchanged.
            assert_eq!(dist.bounds.len(), dist.dendrogram.merges().len());
            assert!(quality::merge_quality_ratio(&dist.bounds) <= 1.0 + eps + 1e-12);
        }
    }

    #[test]
    fn dist_approx_zero_epsilon_degenerates_to_dist_rac() {
        let g = data::grid1d_graph(150, 5);
        for l in Linkage::SPARSE_REDUCIBLE {
            let exact = DistRacEngine::new(&g, l, DistConfig::new(4, 2)).run();
            let approx = DistApproxEngine::new(&g, l, DistConfig::new(4, 2), 0.0).run();
            assert_eq!(
                exact.dendrogram.bitwise_merges(),
                approx.dendrogram.bitwise_merges(),
                "{l:?}"
            );
        }
    }

    #[test]
    fn dist_approx_single_machine_is_silent() {
        let g = data::grid1d_graph(64, 7);
        let (r, report) =
            DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(1, 4), 0.5).run_detailed();
        assert_eq!(r.dendrogram.merges().len(), 63);
        assert_eq!(r.metrics.total_net_messages(), 0);
        assert_eq!(r.metrics.total_net_bytes(), 0);
        assert!(report.batches.is_empty());
    }

    #[test]
    fn dist_approx_traffic_is_cross_shard_and_accounted() {
        let g = data::grid1d_graph(80, 3);
        let (r, report) =
            DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(3, 2), 0.3).run_detailed();
        assert!(r.metrics.total_net_messages() > 0, "caches must be exchanged");
        for b in &report.batches {
            assert_ne!(b.src, b.dst);
            assert!(b.bytes >= b.messages);
        }
        assert_eq!(r.metrics.total_net_messages(), report.total_batches());
        assert_eq!(r.metrics.total_net_bytes(), report.total_bytes());
        // The ε sweep reads whole rows: the scan accounting must show it.
        assert!(r.metrics.rounds[0].eligibility_scan_entries > 0);
    }

    #[test]
    fn dist_approx_more_machines_than_clusters() {
        let g = data::grid1d_graph(5, 1);
        let r = DistApproxEngine::new(&g, Linkage::Single, DistConfig::new(16, 4), 0.5).run();
        assert_eq!(r.dendrogram.merges().len(), 4);
    }

    #[test]
    #[should_panic(expected = "epsilon")]
    fn dist_approx_rejects_negative_epsilon() {
        let g = data::grid1d_graph(4, 0);
        DistApproxEngine::new(&g, Linkage::Average, DistConfig::default(), -0.1);
    }

    #[test]
    #[should_panic(expected = "reducible")]
    fn dist_approx_rejects_centroid() {
        let g = data::stable_hierarchy(2, 4.0, 0);
        DistApproxEngine::new(&g, Linkage::Centroid, DistConfig::default(), 0.1);
    }

    // ------------------------------------------------------------------
    // dist_approx, batched sync mode
    // ------------------------------------------------------------------

    #[test]
    fn batched_local_rounds_are_silent_and_sync_points_counted() {
        let g = data::grid1d_graph(96, 11);
        let (r, report) = DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(3, 2), 0.5)
            .with_sync_mode(SyncMode::Batched { vshards: 8 })
            .run_detailed();
        assert_eq!(r.dendrogram.merges().len(), 95);
        let rounds = r.metrics.rounds.len();
        let syncs = r.metrics.total_sync_points();
        assert!(syncs >= 1, "termination requires at least one sync");
        assert!(
            syncs < rounds,
            "a grid with vshards < n must batch some local rounds ({syncs} of {rounds})"
        );
        // Wire traffic only at sync boundaries.
        for rm in &r.metrics.rounds {
            assert!(rm.sync_points <= 1);
            if rm.sync_points == 0 {
                assert_eq!(rm.net_messages, 0, "round {}: local round sent", rm.round);
                assert_eq!(rm.net_bytes, 0);
            }
        }
        let sync_rounds: Vec<usize> = r
            .metrics
            .rounds
            .iter()
            .filter(|rm| rm.sync_points == 1)
            .map(|rm| rm.round)
            .collect();
        for b in &report.batches {
            assert!(
                sync_rounds.contains(&b.round),
                "batch in non-sync round {}",
                b.round
            );
        }
    }

    #[test]
    fn batched_per_round_engines_count_every_round_as_a_sync() {
        let g = data::grid1d_graph(64, 3);
        let exact = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(3, 1)).run();
        assert_eq!(
            exact.metrics.total_sync_points(),
            exact.metrics.rounds.len()
        );
        let approx =
            DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(3, 1), 0.2).run();
        assert_eq!(
            approx.metrics.total_sync_points(),
            approx.metrics.rounds.len()
        );
    }

    #[test]
    fn batched_dendrogram_is_topology_invariant_bitwise() {
        let g = data::grid1d_graph(120, 7);
        for eps in [0.0, 0.3] {
            let base = DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(1, 1), eps)
                .with_sync_mode(SyncMode::Batched { vshards: 8 })
                .run();
            for (machines, cores) in [(3usize, 2usize), (7, 4)] {
                let r = DistApproxEngine::new(
                    &g,
                    Linkage::Average,
                    DistConfig::new(machines, cores),
                    eps,
                )
                .with_sync_mode(SyncMode::Batched { vshards: 8 })
                .run();
                assert_eq!(
                    base.dendrogram.bitwise_merges(),
                    r.dendrogram.bitwise_merges(),
                    "eps={eps} topology=({machines},{cores})"
                );
                assert_eq!(
                    base.metrics.total_sync_points(),
                    r.metrics.total_sync_points(),
                    "sync schedule must be a pure function of (n, vshards)"
                );
            }
        }
    }

    #[test]
    fn batched_single_machine_is_silent() {
        let g = data::grid1d_graph(64, 7);
        let (r, report) = DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(1, 4), 0.5)
            .with_sync_mode(SyncMode::Batched { vshards: 8 })
            .run_detailed();
        assert_eq!(r.dendrogram.merges().len(), 63);
        assert_eq!(r.metrics.total_net_messages(), 0);
        assert!(report.batches.is_empty());
        // The sync schedule is still counted (it is traffic-independent).
        assert!(r.metrics.total_sync_points() >= 1);
    }

    #[test]
    #[should_panic(expected = "vshards")]
    fn batched_rejects_zero_vshards() {
        let g = data::grid1d_graph(8, 0);
        DistApproxEngine::new(&g, Linkage::Average, DistConfig::default(), 0.1)
            .with_sync_mode(SyncMode::Batched { vshards: 0 });
    }
}
