//! The distributed RAC engine (paper §5): the same three phases as
//! [`crate::rac::RacEngine`], sharded across simulated machines with
//! batched cross-shard messaging and first-class network accounting.
//!
//! ## Shard model
//!
//! Clusters are hash-partitioned over `machines` workers by id
//! ([`shard::shard_of`]); a merged cluster keeps its leader's id, so
//! ownership never migrates and every shard can locate any cluster's owner
//! without coordination. Each round runs the paper's phases as bulk
//! barriers, and every piece of state a shard needs from another shard is
//! staged as a [`network::Message`] and batched per ordered machine pair —
//! one RPC per non-empty pair per *communication step* (the merge phase
//! has two steps: the fetch/lookup exchange before computing unions, and
//! the patch push after applying them):
//!
//! 1. **Find reciprocal NNs** — NN-pointer queries/replies for clusters
//!    whose cached nearest neighbor lives on another shard.
//! 2. **Update dissimilarities** — leaders with a remote partner fetch the
//!    partner's full neighbor map ([`network::Message::PartnerState`]);
//!    pair views of remote neighbors are queried; patches to remote
//!    non-merging neighbors ship as [`network::Message::EdgePatch`].
//! 3. **Update nearest neighbors** — purely local rescans (the patches of
//!    phase 2 already delivered everything a survivor needs).
//!
//! ## Accounting, not emulation
//!
//! This is a single-process *simulation*: the round computation reads the
//! authoritative global state directly (bit-identical to the shared-memory
//! engine, so Theorem 1 exactness transfers verbatim and the dendrogram is
//! independent of the `(machines, cores)` topology), while every
//! cross-shard batch is encoded through the real wire codec and accounted
//! at its exact encoded length. Per round this produces `net_messages`
//! (batched RPCs), `net_bytes` (wire bytes), and `t_sim` — a
//! critical-path time model (max per-machine work per barrier phase,
//! divided by cores for cluster-parallel phases, plus latency and
//! bandwidth terms) corresponding to paper Table 2's resource columns.
//! With `machines == 1` nothing ever crosses a shard boundary and all
//! three counters are exactly zero.
//!
//! The former `coordinator` module stub was folded into this engine:
//! [`DistRacEngine::run`] *is* the round orchestrator.

pub mod network;
pub mod shard;

pub use network::{decode_batch, encode_batch, BatchRecord, Message, NetReport, Network};
pub use shard::{partition, shard_of, ShardLoad};

use std::time::{Duration, Instant};

use rustc_hash::FxHashSet;

use crate::dendrogram::{Dendrogram, Merge};
use crate::graph::Graph;
use crate::linkage::{EdgeState, Linkage, Weight};
use crate::metrics::{RoundMetrics, RunMetrics};
use crate::rac::logic::{compute_union_map, scan_nn, PairView};
use crate::rac::{RacResult, NO_NN};
use crate::store::NeighborStore;

/// Simulated cost of one work unit (one neighbor entry / flag op).
const T_UNIT_NS: u128 = 200;
/// Simulated per-RPC latency (one batched cross-shard message).
const T_MSG_NS: u128 = 50_000;
/// Simulated per-byte cost (~1 GB/s effective cross-machine bandwidth).
const T_BYTE_NS: u128 = 1;

/// Deployment topology for the distributed engine (paper Fig 3's knobs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DistConfig {
    /// Number of shards / machines (≥ 1).
    pub machines: usize,
    /// Worker cores per machine; affects only the simulated critical-path
    /// time `t_sim`, never the result (≥ 1).
    pub cores_per_machine: usize,
}

impl DistConfig {
    /// Build a topology; both knobs are clamped to at least 1.
    pub fn new(machines: usize, cores_per_machine: usize) -> DistConfig {
        DistConfig {
            machines: machines.max(1),
            cores_per_machine: cores_per_machine.max(1),
        }
    }
}

impl Default for DistConfig {
    /// Matches the config-file defaults (`machines = 4`, `cpus = 2`).
    fn default() -> DistConfig {
        DistConfig::new(4, 2)
    }
}

type UnionEntry = crate::store::UnionRow;

/// Distributed RAC engine. Exact: for any topology the dendrogram is
/// bitwise identical to [`crate::rac::RacEngine`]'s and therefore (for
/// reducible linkages) to sequential HAC — Theorem 1.
pub struct DistRacEngine {
    linkage: Linkage,
    cfg: DistConfig,
    n: usize,
    active: Vec<bool>,
    /// Live cluster ids, ascending; compacted once per round.
    active_ids: Vec<u32>,
    size: Vec<u64>,
    nn: Vec<u32>,
    nn_weight: Vec<Weight>,
    will_merge: Vec<bool>,
    /// Flat arena-backed adjacency, shared representation with the
    /// shared-memory engine ([`crate::store`]).
    store: NeighborStore,
    /// Hard cap on rounds (safety valve, as in the shared-memory engine).
    max_rounds: usize,
}

impl DistRacEngine {
    /// Build an engine over a dissimilarity graph.
    ///
    /// # Panics
    /// If the linkage is not reducible (Theorem 1 does not apply), or if a
    /// complete-graph-only linkage is given a sparse graph — the same
    /// guards as the shared-memory engine.
    ///
    /// NOTE: the guards, state initialisation, and the per-phase loop
    /// bodies below are deliberately kept in lockstep with
    /// [`crate::rac::RacEngine`] — the exactness contract is *bitwise*
    /// equality of the two engines' dendrograms (see the
    /// `matches_shared_memory_engine_bitwise` test); change both or
    /// neither.
    pub fn new(g: &Graph, linkage: Linkage, cfg: DistConfig) -> DistRacEngine {
        assert!(
            linkage.is_reducible(),
            "RAC is exact only for reducible linkages (Theorem 1)"
        );
        if !linkage.supports_sparse() {
            let n = g.n();
            assert!(
                g.m() == n * (n - 1) / 2,
                "{linkage:?} linkage requires a complete graph"
            );
        }
        let n = g.n();
        DistRacEngine {
            linkage,
            cfg,
            n,
            active: vec![true; n],
            active_ids: (0..n as u32).collect(),
            size: vec![1; n],
            nn: vec![NO_NN; n],
            nn_weight: vec![Weight::INFINITY; n],
            will_merge: vec![false; n],
            // Rows pre-sized exactly from the CSR degrees — one arena
            // allocation, no per-insert growth.
            store: NeighborStore::from_graph(g),
            max_rounds: 4 * n + 64,
        }
    }

    /// Override the round safety cap.
    pub fn with_max_rounds(mut self, max_rounds: usize) -> DistRacEngine {
        self.max_rounds = max_rounds;
        self
    }

    /// Run to completion; returns the dendrogram and per-round metrics
    /// (including the simulated network columns).
    pub fn run(self) -> RacResult {
        self.run_detailed().0
    }

    /// Like [`run`](Self::run), but also returns the full cross-shard
    /// traffic log for accounting-invariant tests and topology studies.
    pub fn run_detailed(mut self) -> (RacResult, NetReport) {
        let t0 = Instant::now();
        let m = self.cfg.machines;
        let cores = self.cfg.cores_per_machine as u64;
        let mut net = Network::new(m);
        let mut merges: Vec<Merge> = Vec::with_capacity(self.n.saturating_sub(1));
        let mut metrics = RunMetrics::default();

        // Initial NN cache (local per shard: every shard scans only the
        // neighbor rows it owns).
        for c in 0..self.n {
            let (nn, w) = scan_nn(self.store.row(c as u32));
            self.nn[c] = nn;
            self.nn_weight[c] = w;
        }

        let mut n_active = self.n;
        for round in 0..self.max_rounds {
            let mut rm = RoundMetrics {
                round,
                clusters: n_active,
                ..Default::default()
            };
            let mut load = vec![ShardLoad::default(); m];

            // ---- Phase 1: find reciprocal nearest neighbors -------------
            let t = Instant::now();
            self.exchange_nn_pointers(&mut net, &mut load);
            let flags: Vec<bool> = self
                .active_ids
                .iter()
                .map(|&c| {
                    let c = c as usize;
                    self.nn[c] != NO_NN && self.nn[self.nn[c] as usize] == c as u32
                })
                .collect();
            for (&c, flag) in self.active_ids.iter().zip(flags) {
                self.will_merge[c as usize] = flag;
            }
            let leaders: Vec<u32> = self
                .active_ids
                .iter()
                .copied()
                .filter(|&c| self.will_merge[c as usize] && c < self.nn[c as usize])
                .collect();
            rm.t_find = t.elapsed();
            rm.merges = leaders.len();

            if leaders.is_empty() {
                finish_round(&mut rm, &mut net, &load, cores);
                metrics.rounds.push(rm);
                break;
            }

            // ---- Phase 2: update cluster dissimilarities ----------------
            let t = Instant::now();
            let unions = self.compute_unions(&leaders, &mut net, &mut load);
            for &l in &leaders {
                let p = self.nn[l as usize];
                merges.push(Merge {
                    a: l,
                    b: p,
                    weight: self.nn_weight[l as usize],
                });
            }
            self.apply_unions(unions, &mut net);
            n_active -= rm.merges;
            self.active_ids.retain(|&c| self.active[c as usize]);
            rm.t_merge = t.elapsed();

            // ---- Phase 3: update nearest neighbors (local) --------------
            let t = Instant::now();
            let updates: Vec<(u32, u32, Weight, usize)> = self
                .active_ids
                .iter()
                .filter_map(|&c| {
                    let c = c as usize;
                    let needs_rescan = self.will_merge[c]
                        || (self.nn[c] != NO_NN && self.will_merge[self.nn[c] as usize]);
                    needs_rescan.then(|| {
                        let row = self.store.row(c as u32);
                        let (nn, w) = scan_nn(row);
                        (c as u32, nn, w, row.live_len())
                    })
                })
                .collect();
            rm.nn_updates = updates.len();
            for (c, nn, w, scanned) in updates {
                self.nn[c as usize] = nn;
                self.nn_weight[c as usize] = w;
                rm.nn_scan_entries += scanned;
                load[shard_of(c, m)].nn_scan_work += scanned as u64;
            }
            rm.t_update_nn = t.elapsed();

            finish_round(&mut rm, &mut net, &load, cores);
            metrics.rounds.push(rm);

            if n_active <= 1 {
                break;
            }
        }

        metrics.total_time = t0.elapsed();
        (
            RacResult {
                dendrogram: Dendrogram::new(self.n, merges),
                metrics,
            },
            net.into_report(),
        )
    }

    /// Phase-1 traffic: every shard must evaluate `nn(nn(c)) == c` for its
    /// clusters, which needs the NN pointer of each *remote* `nn(c)`.
    /// Queries are deduplicated per (asking shard, target cluster) and
    /// batched per machine pair, replies likewise.
    fn exchange_nn_pointers(&self, net: &mut Network, load: &mut [ShardLoad]) {
        let m = net.machines();
        for &c in &self.active_ids {
            load[shard_of(c, m)].find_work += 1;
        }
        if m == 1 {
            return;
        }
        let mut queries: Vec<Vec<Message>> = vec![Vec::new(); m * m];
        let mut seen: FxHashSet<(usize, u32)> = FxHashSet::default();
        for &c in &self.active_ids {
            let v = self.nn[c as usize];
            if v == NO_NN {
                continue;
            }
            let (src, dst) = (shard_of(c, m), shard_of(v, m));
            if src != dst && seen.insert((src, v)) {
                queries[src * m + dst].push(Message::NnQuery { cluster: v });
            }
        }
        for src in 0..m {
            for dst in 0..m {
                if src == dst {
                    continue;
                }
                let batch = std::mem::take(&mut queries[src * m + dst]);
                if batch.is_empty() {
                    continue;
                }
                let replies: Vec<Message> = batch
                    .iter()
                    .map(|q| match q {
                        Message::NnQuery { cluster } => Message::NnReply {
                            cluster: *cluster,
                            nn: self.nn[*cluster as usize],
                        },
                        _ => unreachable!("phase-1 batches hold only NN queries"),
                    })
                    .collect();
                net.send(src, dst, &batch);
                net.send(dst, src, &replies);
            }
        }
    }

    /// Phase-2 compute: every leader builds the union map of `L ∪ P`
    /// exactly as the shared-memory engine does (same fold, same order),
    /// while the traffic a real deployment would need — partner-state
    /// fetches, remote pair-view lookups — is staged and delivered as
    /// per-pair batches.
    fn compute_unions(
        &self,
        leaders: &[u32],
        net: &mut Network,
        load: &mut [ShardLoad],
    ) -> Vec<UnionEntry> {
        let m = net.machines();
        let mut stage: Vec<Vec<Message>> = vec![Vec::new(); m * m];
        let mut viewed: FxHashSet<(usize, u32)> = FxHashSet::default();
        let mut out = Vec::with_capacity(leaders.len());
        for &l in leaders {
            let p = self.nn[l as usize];
            let (sl, sp) = (shard_of(l, m), shard_of(p, m));
            load[sl].merge_work +=
                (self.store.row(l).live_len() + self.store.row(p).live_len()) as u64;
            if sl != sp {
                stage[sl * m + sp].push(Message::PartnerFetch { partner: p });
                stage[sp * m + sl].push(Message::PartnerState {
                    partner: p,
                    size: self.size[p as usize],
                    entries: self
                        .store
                        .row(p)
                        .iter()
                        .map(|(t, e)| (t, e.weight, e.count))
                        .collect(),
                });
            }
            // Pair views the union computation will request: every
            // neighbor of L or P, plus the partner of any merging
            // neighbor (the canonicalisation step views both members).
            for (x, _) in self.store.row(l).iter().chain(self.store.row(p).iter()) {
                if x == l || x == p {
                    continue;
                }
                self.stage_view(x, sl, m, &mut viewed, &mut stage);
                if self.will_merge[x as usize] {
                    self.stage_view(self.nn[x as usize], sl, m, &mut viewed, &mut stage);
                }
            }
            out.push((l, self.union_map(l, p)));
        }
        for src in 0..m {
            for dst in 0..m {
                if src != dst {
                    net.send(src, dst, &stage[src * m + dst]);
                }
            }
        }
        out
    }

    /// Stage a pair-view query/reply pair for `x` if its owner is not the
    /// asking shard `sl` (deduplicated per shard per round).
    fn stage_view(
        &self,
        x: u32,
        sl: usize,
        m: usize,
        viewed: &mut FxHashSet<(usize, u32)>,
        stage: &mut [Vec<Message>],
    ) {
        let sx = shard_of(x, m);
        if sx == sl || !viewed.insert((sl, x)) {
            return;
        }
        stage[sl * m + sx].push(Message::PairViewQuery { cluster: x });
        stage[sx * m + sl].push(Message::PairViewReply {
            cluster: x,
            merging: self.will_merge[x as usize],
            partner: self.nn[x as usize],
            size: self.size[x as usize],
            pair_weight: self.nn_weight[x as usize],
        });
    }

    /// Phase-2 apply, in ascending leader order (identical to the
    /// shared-memory engine): install unions, retire partners, patch
    /// non-merging neighbors — shipping each patch whose target lives on
    /// another shard.
    fn apply_unions(&mut self, unions: Vec<UnionEntry>, net: &mut Network) {
        let m = net.machines();
        let mut patches: Vec<Vec<Message>> = vec![Vec::new(); m * m];
        for (l, map) in unions {
            let p = self.nn[l as usize];
            let sl = shard_of(l, m);
            for &(t_id, e) in &map {
                if !self.will_merge[t_id as usize] {
                    self.store.patch(t_id, l, p, e);
                    let st = shard_of(t_id, m);
                    if st != sl {
                        patches[sl * m + st].push(Message::EdgePatch {
                            target: t_id,
                            leader: l,
                            retired: p,
                            weight: e.weight,
                            count: e.count,
                        });
                    }
                }
            }
            self.size[l as usize] += self.size[p as usize];
            self.store.install_row(l, &map);
            self.store.clear_row(p);
            self.active[p as usize] = false;
        }
        // Same per-round compaction point as the shared-memory engine, so
        // the two stores' live/dead trajectories stay in lockstep.
        self.store.maybe_compact();
        for src in 0..m {
            for dst in 0..m {
                if src != dst {
                    net.send(src, dst, &patches[src * m + dst]);
                }
            }
        }
    }

    /// Neighbor map of the union `L ∪ P` — delegates to the engine-shared
    /// [`compute_union_map`] with the same arguments as the shared-memory
    /// engine, so the arithmetic (and its floating-point rounding) is
    /// bitwise identical.
    fn union_map(&self, l: u32, p: u32) -> Vec<(u32, EdgeState)> {
        compute_union_map(
            self.linkage,
            l,
            p,
            self.nn_weight[l as usize],
            self.size[l as usize],
            self.size[p as usize],
            self.store.row(l),
            self.store.row(p),
            |x| PairView {
                merging: self.will_merge[x as usize],
                partner: self.nn[x as usize],
                size: self.size[x as usize],
                pair_weight: self.nn_weight[x as usize],
            },
        )
    }
}

/// Close a round: pull the network counters into the metrics and evaluate
/// the critical-path time model. Each phase is a barrier, so its simulated
/// duration is the maximum per-machine work, divided by the cores each
/// machine parallelises cluster-level work across; the network contributes
/// a latency term per batched RPC and a bandwidth term per wire byte.
fn finish_round(rm: &mut RoundMetrics, net: &mut Network, load: &[ShardLoad], cores: u64) {
    let (msgs, bytes) = net.end_round();
    rm.net_messages = msgs;
    rm.net_bytes = bytes;
    let phase_max = |f: fn(&ShardLoad) -> u64| load.iter().map(f).max().unwrap_or(0);
    let compute = phase_max(|s| s.find_work).div_ceil(cores)
        + phase_max(|s| s.merge_work).div_ceil(cores)
        + phase_max(|s| s.nn_scan_work).div_ceil(cores);
    let ns = compute as u128 * T_UNIT_NS + msgs as u128 * T_MSG_NS + bytes as u128 * T_BYTE_NS;
    rm.t_sim = Duration::from_nanos(ns.min(u64::MAX as u128) as u64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::hac::naive_hac;

    #[test]
    fn default_config_is_clamped_and_copy() {
        let cfg = DistConfig::new(0, 0);
        assert_eq!(cfg, DistConfig::new(1, 1));
        let d = DistConfig::default();
        assert_eq!((d.machines, d.cores_per_machine), (4, 2));
        let copy = d; // Copy, not move
        assert_eq!(copy, d);
    }

    #[test]
    fn two_points_across_two_shards() {
        let g = Graph::from_edges(2, [(0, 1, 3.5)]);
        let (r, report) = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(2, 1))
            .run_detailed();
        assert_eq!(r.dendrogram.merges().len(), 1);
        assert_eq!(r.dendrogram.merges()[0].weight, 3.5);
        // Node 1's NN pointer lives on shard 0 and vice versa: the find
        // phase must have exchanged pointers.
        assert!(r.metrics.total_net_messages() > 0);
        assert!(report.batches.iter().all(|b| b.src != b.dst));
    }

    #[test]
    fn more_machines_than_clusters() {
        // Shards 5..15 own nothing; the engine must not stumble on them.
        let g = data::grid1d_graph(5, 1);
        let r = DistRacEngine::new(&g, Linkage::Single, DistConfig::new(16, 4)).run();
        assert_eq!(r.dendrogram.merges().len(), 4);
        let hac = naive_hac(&g, Linkage::Single);
        assert!(hac.same_clustering(&r.dendrogram, 1e-12));
    }

    #[test]
    fn empty_and_singleton() {
        let r = DistRacEngine::new(&Graph::from_edges(0, []), Linkage::Average, DistConfig::new(3, 1))
            .run();
        assert!(r.dendrogram.merges().is_empty());
        assert_eq!(r.metrics.total_net_bytes(), 0);
        let r = DistRacEngine::new(&Graph::from_edges(1, []), Linkage::Average, DistConfig::new(3, 1))
            .run();
        assert!(r.dendrogram.merges().is_empty());
    }

    #[test]
    fn single_machine_is_silent_and_exact() {
        let g = data::grid1d_graph(64, 7);
        let (r, report) =
            DistRacEngine::new(&g, Linkage::Average, DistConfig::new(1, 8)).run_detailed();
        assert_eq!(r.metrics.total_net_messages(), 0);
        assert_eq!(r.metrics.total_net_bytes(), 0);
        assert!(report.batches.is_empty());
        assert!(r.metrics.total_sim_time().as_nanos() > 0);
        let hac = naive_hac(&g, Linkage::Average);
        assert!(hac.same_clustering(&r.dendrogram, 1e-12));
    }

    #[test]
    fn matches_shared_memory_engine_bitwise() {
        let g = data::grid1d_graph(200, 17);
        for l in Linkage::SPARSE_REDUCIBLE {
            let shared = crate::rac::RacEngine::new(&g, l).run();
            let dist = DistRacEngine::new(&g, l, DistConfig::new(5, 3)).run();
            let a: Vec<_> = shared
                .dendrogram
                .merges()
                .iter()
                .map(|m| (m.a, m.b, m.weight.to_bits()))
                .collect();
            let b: Vec<_> = dist
                .dendrogram
                .merges()
                .iter()
                .map(|m| (m.a, m.b, m.weight.to_bits()))
                .collect();
            assert_eq!(a, b, "{l:?}: dist must mirror the shared engine bitwise");
        }
    }

    #[test]
    fn max_rounds_zero_produces_empty_run() {
        let g = data::grid1d_graph(10, 1);
        let r = DistRacEngine::new(&g, Linkage::Single, DistConfig::default())
            .with_max_rounds(0)
            .run();
        assert!(r.dendrogram.merges().is_empty());
        assert!(r.metrics.rounds.is_empty());
    }

    #[test]
    #[should_panic(expected = "reducible")]
    fn rejects_centroid() {
        let g = data::stable_hierarchy(2, 4.0, 0);
        DistRacEngine::new(&g, Linkage::Centroid, DistConfig::default());
    }

    #[test]
    fn sim_time_scales_down_with_cores() {
        let g = data::grid1d_graph(400, 3);
        let slow = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(4, 1)).run();
        let fast = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(4, 8)).run();
        assert!(slow.dendrogram.same_clustering(&fast.dendrogram, 1e-15));
        assert!(
            fast.metrics.total_sim_time() < slow.metrics.total_sim_time(),
            "more cores per machine must shorten the simulated critical path"
        );
    }
}
