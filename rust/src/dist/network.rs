//! Batched cross-shard messaging: the wire codec and the per-round
//! traffic accounting that backs `RoundMetrics::{net_messages, net_bytes}`
//! (paper Table 2's "network" resource).
//!
//! All cross-shard communication in a round is staged per ordered machine
//! pair and delivered as one batched RPC per non-empty pair — the paper's
//! batching discipline, which makes message count scale with the topology
//! (`O(machines²)` per phase) while byte count scales with the data. A
//! batch is accounted at exactly its encoded wire length; the codec is a
//! plain little-endian tag + fields layout, round-trip-tested below and
//! `debug_assert`-verified on every live send.

use crate::linkage::Weight;

/// One logical message between shards. Payload sizes mirror what a real
/// deployment would ship: ids are `u32`, sizes/counts `u64`, weights `f64`.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Find phase: ask `cluster`'s owner for its nearest-neighbor pointer
    /// (needed to evaluate `nn(nn(c)) == c` when `nn(c)` is remote).
    NnQuery { cluster: u32 },
    /// Find phase: the owner's answer.
    NnReply { cluster: u32, nn: u32 },
    /// Merge phase: a leader requests its remote partner's state.
    PartnerFetch { partner: u32 },
    /// Merge phase: the partner's full neighbor map and size, shipped to
    /// the leader's shard so it can compute the union map.
    PartnerState {
        partner: u32,
        size: u64,
        /// `(target, weight, count)` neighbor entries.
        entries: Vec<(u32, Weight, u64)>,
    },
    /// Merge phase: ask a remote neighbor's owner for its pair view
    /// (merge flag, partner, size, pair weight).
    PairViewQuery { cluster: u32 },
    /// Merge phase: the owner's answer.
    PairViewReply {
        cluster: u32,
        merging: bool,
        partner: u32,
        size: u64,
        pair_weight: Weight,
    },
    /// Merge phase: patch a remote non-merging neighbor's map — drop the
    /// edge to the retired partner, install the edge to the new union.
    EdgePatch {
        target: u32,
        leader: u32,
        retired: u32,
        weight: Weight,
        count: u64,
    },
    /// Approx find phase: ask `cluster`'s owner for its cached NN *edge*
    /// (the ε-good test needs the weight as well as the pointer).
    NnCacheQuery { cluster: u32 },
    /// Approx find phase: the owner's answer.
    NnCacheReply {
        cluster: u32,
        nn: u32,
        weight: Weight,
    },
    /// Approx find phase: a shard ships its locally-discovered ε-good
    /// candidate edges `(weight, a, b)` to the matching coordinator.
    CandidateBatch { edges: Vec<(Weight, u32, u32)> },
    /// Approx find phase: the coordinator broadcasts the selected maximal
    /// matching `(leader, partner, weight)` to the shards that own active
    /// clusters.
    MatchingBroadcast { pairs: Vec<(u32, u32, Weight)> },
}

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Convert a container length to its `u32` wire prefix, failing loudly on
/// overflow instead of silently truncating (a wrapped prefix would decode
/// as a *valid* short batch on the other side — the worst kind of
/// corruption, because nothing downstream can detect it).
pub(crate) fn len_u32(len: usize, what: &str) -> u32 {
    u32::try_from(len)
        .unwrap_or_else(|_| panic!("{what} length {len} exceeds the u32 wire-prefix limit"))
}

/// Little-endian cursor over an encoded batch. Also reused by the
/// checkpoint codec ([`crate::dist::checkpoint`]), which faces the same
/// hostile-bytes concerns when restoring state from a snapshot.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    /// Bytes not yet consumed. (`pos <= buf.len()` is an invariant:
    /// `take` only ever advances to a validated end offset.)
    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current cursor offset.
    pub(crate) fn pos(&self) -> usize {
        self.pos
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&end| end <= self.buf.len())
            .ok_or_else(|| {
                format!(
                    "truncated batch: wanted {n} bytes at offset {}, have {} remaining",
                    self.pos,
                    self.remaining()
                )
            })?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Guard an element-count prefix *before* the element loop: with
    /// fewer than `len * min_elem_size` bytes remaining, the prefix is
    /// corrupt no matter what the elements contain. Rejecting here (a)
    /// caps `Vec::with_capacity(len)` at a value the buffer itself
    /// justifies — an attacker cannot make us reserve gigabytes with a
    /// 4-byte prefix — and (b) turns a long walk to an eventual `take`
    /// error into an immediate one.
    pub(crate) fn check_count(
        &self,
        len: usize,
        min_elem_size: usize,
        what: &str,
    ) -> Result<(), String> {
        debug_assert!(min_elem_size > 0);
        if len > self.remaining() / min_elem_size {
            return Err(format!(
                "corrupt {what} count {len}: needs at least {min_elem_size} \
                 bytes per element but only {} remain",
                self.remaining()
            ));
        }
        Ok(())
    }
}

fn encode_message(msg: &Message, buf: &mut Vec<u8>) {
    match msg {
        Message::NnQuery { cluster } => {
            buf.push(0);
            put_u32(buf, *cluster);
        }
        Message::NnReply { cluster, nn } => {
            buf.push(1);
            put_u32(buf, *cluster);
            put_u32(buf, *nn);
        }
        Message::PartnerFetch { partner } => {
            buf.push(2);
            put_u32(buf, *partner);
        }
        Message::PartnerState {
            partner,
            size,
            entries,
        } => {
            buf.push(3);
            put_u32(buf, *partner);
            put_u64(buf, *size);
            put_u32(buf, len_u32(entries.len(), "PartnerState entries"));
            for &(t, w, c) in entries {
                put_u32(buf, t);
                put_f64(buf, w);
                put_u64(buf, c);
            }
        }
        Message::PairViewQuery { cluster } => {
            buf.push(4);
            put_u32(buf, *cluster);
        }
        Message::PairViewReply {
            cluster,
            merging,
            partner,
            size,
            pair_weight,
        } => {
            buf.push(5);
            put_u32(buf, *cluster);
            buf.push(u8::from(*merging));
            put_u32(buf, *partner);
            put_u64(buf, *size);
            put_f64(buf, *pair_weight);
        }
        Message::EdgePatch {
            target,
            leader,
            retired,
            weight,
            count,
        } => {
            buf.push(6);
            put_u32(buf, *target);
            put_u32(buf, *leader);
            put_u32(buf, *retired);
            put_f64(buf, *weight);
            put_u64(buf, *count);
        }
        Message::NnCacheQuery { cluster } => {
            buf.push(7);
            put_u32(buf, *cluster);
        }
        Message::NnCacheReply {
            cluster,
            nn,
            weight,
        } => {
            buf.push(8);
            put_u32(buf, *cluster);
            put_u32(buf, *nn);
            put_f64(buf, *weight);
        }
        Message::CandidateBatch { edges } => {
            buf.push(9);
            put_u32(buf, len_u32(edges.len(), "CandidateBatch edges"));
            for &(w, a, b) in edges {
                put_f64(buf, w);
                put_u32(buf, a);
                put_u32(buf, b);
            }
        }
        Message::MatchingBroadcast { pairs } => {
            buf.push(10);
            put_u32(buf, len_u32(pairs.len(), "MatchingBroadcast pairs"));
            for &(a, b, w) in pairs {
                put_u32(buf, a);
                put_u32(buf, b);
                put_f64(buf, w);
            }
        }
    }
}

fn decode_message(r: &mut Reader<'_>) -> Result<Message, String> {
    let tag = r.u8()?;
    Ok(match tag {
        0 => Message::NnQuery { cluster: r.u32()? },
        1 => Message::NnReply {
            cluster: r.u32()?,
            nn: r.u32()?,
        },
        2 => Message::PartnerFetch { partner: r.u32()? },
        3 => {
            let partner = r.u32()?;
            let size = r.u64()?;
            let len = r.u32()? as usize;
            // (target u32, weight f64, count u64) = 20 bytes minimum.
            r.check_count(len, 20, "PartnerState entry")?;
            let mut entries = Vec::with_capacity(len);
            for _ in 0..len {
                entries.push((r.u32()?, r.f64()?, r.u64()?));
            }
            Message::PartnerState {
                partner,
                size,
                entries,
            }
        }
        4 => Message::PairViewQuery { cluster: r.u32()? },
        5 => Message::PairViewReply {
            cluster: r.u32()?,
            merging: r.u8()? != 0,
            partner: r.u32()?,
            size: r.u64()?,
            pair_weight: r.f64()?,
        },
        6 => Message::EdgePatch {
            target: r.u32()?,
            leader: r.u32()?,
            retired: r.u32()?,
            weight: r.f64()?,
            count: r.u64()?,
        },
        7 => Message::NnCacheQuery { cluster: r.u32()? },
        8 => Message::NnCacheReply {
            cluster: r.u32()?,
            nn: r.u32()?,
            weight: r.f64()?,
        },
        9 => {
            let len = r.u32()? as usize;
            // (weight f64, a u32, b u32) = 16 bytes minimum.
            r.check_count(len, 16, "CandidateBatch edge")?;
            let mut edges = Vec::with_capacity(len);
            for _ in 0..len {
                edges.push((r.f64()?, r.u32()?, r.u32()?));
            }
            Message::CandidateBatch { edges }
        }
        10 => {
            let len = r.u32()? as usize;
            // (leader u32, partner u32, weight f64) = 16 bytes minimum.
            r.check_count(len, 16, "MatchingBroadcast pair")?;
            let mut pairs = Vec::with_capacity(len);
            for _ in 0..len {
                pairs.push((r.u32()?, r.u32()?, r.f64()?));
            }
            Message::MatchingBroadcast { pairs }
        }
        other => return Err(format!("unknown message tag {other}")),
    })
}

/// Encode a batch: `u32` message count, then each message.
pub fn encode_batch(msgs: &[Message]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(4 + 16 * msgs.len());
    put_u32(&mut buf, len_u32(msgs.len(), "batch message"));
    for m in msgs {
        encode_message(m, &mut buf);
    }
    buf
}

/// Decode a batch; rejects truncation, unknown tags, and trailing bytes.
pub fn decode_batch(bytes: &[u8]) -> Result<Vec<Message>, String> {
    let mut r = Reader::new(bytes);
    let len = r.u32()? as usize;
    // Every message encodes to at least its 1-byte tag.
    r.check_count(len, 1, "batch message")?;
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        out.push(decode_message(&mut r)?);
    }
    if r.pos != bytes.len() {
        return Err(format!(
            "{} trailing bytes after {len} messages",
            bytes.len() - r.pos
        ));
    }
    Ok(out)
}

/// One accounted cross-shard batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchRecord {
    pub src: usize,
    pub dst: usize,
    /// Logical messages inside the batch (always ≥ 1).
    pub messages: usize,
    /// Encoded wire length (always ≥ the message count: every message
    /// encodes to at least one byte — the per-round `net_bytes >=
    /// net_messages` invariant follows).
    pub bytes: usize,
    /// Engine round the batch was sent in (0-based; rounds advance at
    /// [`Network::end_round`]). Lets the batching suite assert that wire
    /// traffic only flows at synchronisation rounds.
    pub round: usize,
}

/// One journaled wire packet: the [`BatchRecord`] key promoted to a full
/// inbound-traffic journal entry — `(src, dst, round, step)` plus the
/// encoded payload. The executed driver keeps every packet shipped since
/// the last checkpoint cut (empty barrier packets included, because a
/// replayed collect blocks on them like any other), so `shard_replay`
/// recovery can respawn one dead machine and re-feed it exactly the bytes
/// it saw the first time, while the survivors idle at the barrier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JournalRecord {
    pub src: usize,
    pub dst: usize,
    /// Engine round the packet belongs to (0-based).
    pub round: usize,
    /// Exchange step within the round (unique per round; see
    /// [`crate::dist::exec`]'s step constants).
    pub step: u8,
    /// Encoded batch payload, exactly as shipped (possibly the 4-byte
    /// empty batch that carries only the barrier).
    pub bytes: Vec<u8>,
}

/// The simulated interconnect: counts batched RPCs and payload bytes per
/// round. Intra-machine delivery is free and never recorded — batches are
/// cross-shard by construction (asserted).
#[derive(Debug)]
pub struct Network {
    machines: usize,
    round: usize,
    round_messages: usize,
    round_bytes: usize,
    batches: Vec<BatchRecord>,
}

/// Full-run traffic log, returned by `DistRacEngine::run_detailed` for
/// accounting-invariant tests and topology studies.
#[derive(Debug, Clone, Default)]
pub struct NetReport {
    pub batches: Vec<BatchRecord>,
}

impl NetReport {
    pub fn total_bytes(&self) -> usize {
        self.batches.iter().map(|b| b.bytes).sum()
    }

    pub fn total_batches(&self) -> usize {
        self.batches.len()
    }
}

impl Network {
    pub fn new(machines: usize) -> Network {
        Network {
            machines: machines.max(1),
            round: 0,
            round_messages: 0,
            round_bytes: 0,
            batches: Vec::new(),
        }
    }

    pub fn machines(&self) -> usize {
        self.machines
    }

    /// Account one batched cross-shard RPC. Empty batches are skipped;
    /// `src == dst` is a caller bug (local work must not touch the
    /// network).
    pub fn send(&mut self, src: usize, dst: usize, msgs: &[Message]) {
        if msgs.is_empty() {
            return;
        }
        assert_ne!(src, dst, "network batches must be cross-shard");
        assert!(src < self.machines && dst < self.machines);
        let wire = encode_batch(msgs);
        debug_assert_eq!(
            decode_batch(&wire).as_deref(),
            Ok(msgs),
            "codec round-trip violated"
        );
        self.round_messages += 1;
        self.round_bytes += wire.len();
        self.batches.push(BatchRecord {
            src,
            dst,
            messages: msgs.len(),
            bytes: wire.len(),
            round: self.round,
        });
    }

    /// Close the round: return and reset `(net_messages, net_bytes)` and
    /// advance the round stamp subsequent batches carry.
    pub fn end_round(&mut self) -> (usize, usize) {
        let out = (self.round_messages, self.round_bytes);
        self.round += 1;
        self.round_messages = 0;
        self.round_bytes = 0;
        out
    }

    /// Consume the network into its full-run traffic log.
    pub fn into_report(self) -> NetReport {
        NetReport {
            batches: self.batches,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_batch() -> Vec<Message> {
        vec![
            Message::NnQuery { cluster: 7 },
            Message::NnReply {
                cluster: 7,
                nn: u32::MAX,
            },
            Message::PartnerFetch { partner: 19 },
            Message::PartnerState {
                partner: 19,
                size: 1 << 40,
                entries: vec![(3, 1.25, 4), (9, f64::INFINITY, 1)],
            },
            Message::PairViewQuery { cluster: 2 },
            Message::PairViewReply {
                cluster: 2,
                merging: true,
                partner: 5,
                size: 12,
                pair_weight: 0.125,
            },
            Message::EdgePatch {
                target: 11,
                leader: 2,
                retired: 5,
                weight: 3.5,
                count: 8,
            },
            Message::NnCacheQuery { cluster: 31 },
            Message::NnCacheReply {
                cluster: 31,
                nn: 4,
                weight: 0.75,
            },
            Message::CandidateBatch {
                edges: vec![(1.5, 0, 9), (2.25, 3, 4)],
            },
            Message::MatchingBroadcast {
                pairs: vec![(0, 9, 1.5)],
            },
        ]
    }

    #[test]
    fn empty_payload_vectors_round_trip() {
        let msgs = vec![
            Message::CandidateBatch { edges: vec![] },
            Message::MatchingBroadcast { pairs: vec![] },
        ];
        assert_eq!(decode_batch(&encode_batch(&msgs)).unwrap(), msgs);
    }

    #[test]
    fn batch_round_trips_exactly() {
        let msgs = sample_batch();
        let wire = encode_batch(&msgs);
        assert_eq!(decode_batch(&wire).unwrap(), msgs);
        // Empty batch round-trips too.
        assert_eq!(decode_batch(&encode_batch(&[])).unwrap(), Vec::new());
    }

    #[test]
    fn byte_accounting_matches_encoded_length() {
        let msgs = sample_batch();
        let wire = encode_batch(&msgs);
        let mut net = Network::new(3);
        net.send(0, 2, &msgs);
        let (m, b) = net.end_round();
        assert_eq!(m, 1, "one batch = one accounted message");
        assert_eq!(b, wire.len(), "bytes must equal the wire length");
        let report = net.into_report();
        assert_eq!(report.total_bytes(), wire.len());
        assert_eq!(report.batches[0].messages, msgs.len());
    }

    #[test]
    fn truncated_batches_are_rejected() {
        let wire = encode_batch(&sample_batch());
        for cut in [0usize, 3, 5, wire.len() / 2, wire.len() - 1] {
            assert!(decode_batch(&wire[..cut]).is_err(), "cut={cut} accepted");
        }
        // Trailing garbage is rejected as well.
        let mut extended = wire.clone();
        extended.push(0xFF);
        assert!(decode_batch(&extended).is_err());
        // Unknown tag.
        assert!(decode_batch(&[1, 0, 0, 0, 99]).is_err());
    }

    #[test]
    fn empty_sends_are_free_and_rounds_reset() {
        let mut net = Network::new(4);
        net.send(1, 3, &[]);
        assert_eq!(net.end_round(), (0, 0));
        net.send(1, 3, &[Message::NnQuery { cluster: 0 }]);
        let (m, b) = net.end_round();
        assert_eq!(m, 1);
        assert!(b >= m, "net_bytes >= net_messages");
        assert_eq!(net.end_round(), (0, 0), "counters reset per round");
    }

    #[test]
    fn batches_carry_their_round_stamp() {
        let mut net = Network::new(2);
        net.send(0, 1, &[Message::NnQuery { cluster: 0 }]);
        net.end_round();
        net.end_round(); // a silent round advances the stamp too
        net.send(1, 0, &[Message::NnQuery { cluster: 1 }]);
        net.end_round();
        let report = net.into_report();
        let rounds: Vec<usize> = report.batches.iter().map(|b| b.round).collect();
        assert_eq!(rounds, vec![0, 2]);
    }

    #[test]
    #[should_panic(expected = "cross-shard")]
    fn local_sends_are_a_bug() {
        let mut net = Network::new(2);
        net.send(1, 1, &[Message::NnQuery { cluster: 0 }]);
    }

    #[test]
    fn oversized_length_prefixes_panic_instead_of_wrapping() {
        // `len_u32` is the guard behind every `put_u32(len)` site; an
        // actual > 4-billion-element vector is not constructible in a
        // test, so pin the helper directly.
        assert_eq!(len_u32(0, "x"), 0);
        assert_eq!(len_u32(u32::MAX as usize, "x"), u32::MAX);
        let oversized = u32::MAX as usize + 1;
        let r = std::panic::catch_unwind(|| len_u32(oversized, "regression"));
        assert!(r.is_err(), "a wrapping prefix must fail loudly");
    }

    #[test]
    fn corrupt_count_prefixes_are_rejected_before_the_element_loop() {
        // A PartnerState claiming u32::MAX entries in a near-empty buffer
        // must be rejected from the prefix alone (no element walk, no
        // giant allocation).
        let mut wire = Vec::new();
        put_u32(&mut wire, 1); // one message in the batch
        wire.push(3); // PartnerState tag
        put_u32(&mut wire, 19); // partner
        put_u64(&mut wire, 1); // size
        put_u32(&mut wire, u32::MAX); // corrupt entry count
        let err = decode_batch(&wire).unwrap_err();
        assert!(err.contains("corrupt"), "want prefix rejection, got: {err}");

        // Same for the batch-level message count.
        let mut wire = Vec::new();
        put_u32(&mut wire, u32::MAX);
        wire.push(0);
        let err = decode_batch(&wire).unwrap_err();
        assert!(err.contains("corrupt"), "want prefix rejection, got: {err}");
    }

    #[test]
    fn reader_take_reports_remaining_bytes_and_survives_overflow() {
        let buf = [0u8; 8];
        let mut r = Reader::new(&buf);
        r.take(5).unwrap();
        let err = r.take(10).unwrap_err();
        assert!(
            err.contains("have 3 remaining"),
            "error must report remaining bytes, got: {err}"
        );
        // An adversarial length near usize::MAX must not overflow the
        // bounds check into an accept.
        let mut r = Reader::new(&buf);
        r.take(4).unwrap();
        assert!(r.take(usize::MAX - 2).is_err());
        assert_eq!(r.remaining(), 4, "failed take must not move the cursor");
    }

    #[test]
    fn non_finite_weights_round_trip_bitwise() {
        let msgs = vec![Message::PairViewReply {
            cluster: 0,
            merging: false,
            partner: u32::MAX,
            size: 1,
            pair_weight: f64::INFINITY,
        }];
        assert_eq!(decode_batch(&encode_batch(&msgs)).unwrap(), msgs);
    }
}
