//! Cluster→machine placement and per-shard bookkeeping.
//!
//! Placement is a pure function of the cluster id ([`shard_of`]), so it is
//! stable across the whole run: a merged cluster keeps its leader's id and
//! therefore its leader's shard, and every machine can compute any
//! cluster's owner locally without a directory service (the paper's
//! hash-partitioned ownership). The id-mod-machines choice also keeps
//! shards balanced as clusters die, because merge survivors are spread
//! uniformly over residues.

/// The machine that owns `cluster` in an `machines`-shard deployment.
#[inline]
pub fn shard_of(cluster: u32, machines: usize) -> usize {
    (cluster as usize) % machines.max(1)
}

/// The *virtual shard* of `cluster`: one of `vshards` contiguous blocks
/// of the initial id space `[0, n)`.
///
/// The batched `dist_approx` engine partitions clusters into these
/// subgraphs and drains (1+ε)-good merges *inside* each block between
/// global synchronisations. Two deliberate properties:
///
/// * **Topology-independent** — the partition is a function of `(n,
///   vshards)` only, never of the machine count, so the batched engine's
///   merge schedule (and hence its dendrogram) is bitwise invariant
///   across `(machines, cpus)` topologies: machines own whole virtual
///   shards ([`Placement::Blocked`] maps `vshard % machines`), which only
///   moves *traffic accounting*, exactly like the exact engine's
///   sharding. `vshards` itself is part of the algorithm configuration
///   (like ε), not a deployment knob.
/// * **Contiguous blocks, not residues** — TeraHAC feeds its subgraph
///   phase with a locality-maximising graph partition; this crate's
///   datasets and generators emit locality-correlated ids (grid paths,
///   hierarchy subtrees, kNN over mixture draws), so contiguous id
///   blocks are the id-space stand-in for that partitioner. Residue
///   classes (`id % vshards`) would put *nearby* clusters on different
///   shards and leave nothing local to merge.
///
/// A merged cluster keeps its leader's (lower) id, so it stays in its
/// leader's block and placement remains a pure id function mid-run.
#[inline]
pub fn vshard_of(cluster: u32, n: usize, vshards: u32) -> u32 {
    debug_assert!((cluster as usize) < n.max(1));
    ((cluster as u64 * vshards as u64) / n.max(1) as u64) as u32
}

/// An [`crate::engine::EdgeScope`] admitting only edges whose endpoints
/// share a virtual shard — plugging this into an
/// [`crate::engine::GoodSelector`] turns the shared round driver into the
/// per-shard local engine of the batched `dist_approx` mode
/// (`rust/tests/dist_batching.rs` pins the equivalence).
#[derive(Debug, Clone, Copy)]
pub struct VShardScope {
    n: usize,
    vshards: u32,
}

impl VShardScope {
    /// Scope over `vshards` blocks of the id space `[0, n)` (`vshards`
    /// clamped to at least 1).
    pub fn new(n: usize, vshards: u32) -> VShardScope {
        VShardScope {
            n,
            vshards: vshards.max(1),
        }
    }
}

impl crate::engine::EdgeScope for VShardScope {
    #[inline]
    fn admits(&self, a: u32, b: u32) -> bool {
        vshard_of(a, self.n, self.vshards) == vshard_of(b, self.n, self.vshards)
    }
}

/// Cluster → machine placement for the distributed engines' traffic
/// accounting. [`Placement::Mod`] is the PR-1 id-residue rule (the
/// per-round engines, unchanged); [`Placement::Blocked`] assigns whole
/// virtual shards to machines so the batched engine's shard-local merges
/// are machine-local by construction. Placement never affects results —
/// only which state accesses cross a machine boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// `cluster % machines` (the exact engines' hash partition).
    Mod { machines: usize },
    /// `vshard_of(cluster) % machines`: contiguous id blocks, each wholly
    /// owned by one machine.
    Blocked {
        n: usize,
        vshards: u32,
        machines: usize,
    },
}

impl Placement {
    /// The machine that owns `cluster`.
    #[inline]
    pub fn machine_of(self, cluster: u32) -> usize {
        match self {
            Placement::Mod { machines } => shard_of(cluster, machines),
            Placement::Blocked {
                n,
                vshards,
                machines,
            } => vshard_of(cluster, n, vshards) as usize % machines.max(1),
        }
    }
}

/// Partition `ids` into per-shard owned lists (order within a shard
/// follows the input order). Every id lands on exactly one shard — the
/// placement is a total partition, property-tested in
/// `rust/tests/dist_sharding.rs`.
pub fn partition(ids: &[u32], machines: usize) -> Vec<Vec<u32>> {
    let m = machines.max(1);
    let mut shards: Vec<Vec<u32>> = vec![Vec::new(); m];
    for &id in ids {
        shards[shard_of(id, m)].push(id);
    }
    shards
}

/// Per-machine work counters for one simulated round, in abstract "work
/// units" (one neighbor-map entry processed, or one per-cluster flag op).
/// Feeds the critical-path time model (`RoundMetrics::t_sim`): each phase
/// is a barrier, so its simulated duration is the *maximum* unit count
/// across machines, divided by the cores available per machine.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardLoad {
    /// Find-reciprocal-NN phase: per-cluster flag evaluations.
    pub find_work: u64,
    /// Merge phase: union-map entries gathered and folded.
    pub merge_work: u64,
    /// Update-NN phase: neighbor entries scanned during rescans.
    pub nn_scan_work: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_stable_and_in_range() {
        for machines in [1usize, 2, 3, 7, 16] {
            for c in 0..200u32 {
                let s = shard_of(c, machines);
                assert!(s < machines);
                assert_eq!(s, shard_of(c, machines), "placement must be pure");
            }
        }
    }

    #[test]
    fn partition_is_total_and_disjoint() {
        let ids: Vec<u32> = (0..57).map(|i| i * 3 + 1).collect();
        let parts = partition(&ids, 5);
        assert_eq!(parts.len(), 5);
        let total: usize = parts.iter().map(Vec::len).sum();
        assert_eq!(total, ids.len());
        for (s, part) in parts.iter().enumerate() {
            for &id in part {
                assert_eq!(shard_of(id, 5), s);
            }
        }
    }

    #[test]
    fn more_machines_than_clusters_leaves_empty_shards() {
        let parts = partition(&[0, 1, 2], 16);
        assert_eq!(parts.len(), 16);
        assert_eq!(parts[0], vec![0]);
        assert_eq!(parts[1], vec![1]);
        assert_eq!(parts[2], vec![2]);
        assert!(parts[3..].iter().all(Vec::is_empty));
    }

    #[test]
    fn empty_input_and_degenerate_machine_count() {
        assert!(partition(&[], 4).iter().all(Vec::is_empty));
        // machines is clamped to 1, never panics.
        assert_eq!(shard_of(9, 0), 0);
        assert_eq!(partition(&[7], 0).len(), 1);
    }

    #[test]
    fn single_machine_owns_everything() {
        let parts = partition(&[5, 9, 100], 1);
        assert_eq!(parts, vec![vec![5, 9, 100]]);
    }

    #[test]
    fn vshards_are_contiguous_balanced_blocks() {
        // n = 128, V = 8 → blocks of exactly 16 consecutive ids.
        for c in 0..128u32 {
            assert_eq!(vshard_of(c, 128, 8), c / 16, "cluster {c}");
        }
        // Non-dividing n: monotone, in range, every shard non-empty.
        let n = 100;
        let mut prev = 0;
        let mut seen = vec![false; 7];
        for c in 0..n as u32 {
            let v = vshard_of(c, n, 7);
            assert!(v < 7 && v >= prev, "cluster {c}: vshard {v}");
            seen[v as usize] = true;
            prev = v;
        }
        assert!(seen.iter().all(|&s| s), "empty virtual shard");
        // More vshards than ids: still in range (blocks of <= 1).
        assert!(vshard_of(2, 3, 16) < 16);
        // Degenerate n never divides by zero.
        assert_eq!(vshard_of(0, 0, 4), 0);
    }

    #[test]
    fn vshard_scope_admits_only_co_shard_edges() {
        use crate::engine::EdgeScope;
        let scope = VShardScope::new(32, 4); // blocks of 8
        assert!(scope.admits(0, 7));
        assert!(!scope.admits(7, 8));
        assert!(scope.admits(24, 31));
        // vshards clamps to 1 → everything co-shard.
        let all = VShardScope::new(32, 0);
        assert!(all.admits(0, 31));
    }

    #[test]
    fn blocked_placement_keeps_virtual_shards_whole() {
        let place = Placement::Blocked {
            n: 64,
            vshards: 8,
            machines: 3,
        };
        for c in 0..64u32 {
            let v = vshard_of(c, 64, 8);
            assert_eq!(place.machine_of(c), v as usize % 3);
            // Every member of c's block lands on the same machine.
            let block_start = v as usize * 8;
            for m in block_start..block_start + 8 {
                assert_eq!(place.machine_of(m as u32), place.machine_of(c));
            }
        }
        // Mod placement is the PR-1 rule, bit for bit.
        let modp = Placement::Mod { machines: 5 };
        for c in 0..40u32 {
            assert_eq!(modp.machine_of(c), shard_of(c, 5));
        }
    }
}
