//! Cluster→machine placement and per-shard bookkeeping.
//!
//! Placement is a pure function of the cluster id ([`shard_of`]), so it is
//! stable across the whole run: a merged cluster keeps its leader's id and
//! therefore its leader's shard, and every machine can compute any
//! cluster's owner locally without a directory service (the paper's
//! hash-partitioned ownership). The id-mod-machines choice also keeps
//! shards balanced as clusters die, because merge survivors are spread
//! uniformly over residues.

/// The machine that owns `cluster` in an `machines`-shard deployment.
#[inline]
pub fn shard_of(cluster: u32, machines: usize) -> usize {
    (cluster as usize) % machines.max(1)
}

/// Partition `ids` into per-shard owned lists (order within a shard
/// follows the input order). Every id lands on exactly one shard — the
/// placement is a total partition, property-tested in
/// `rust/tests/dist_sharding.rs`.
pub fn partition(ids: &[u32], machines: usize) -> Vec<Vec<u32>> {
    let m = machines.max(1);
    let mut shards: Vec<Vec<u32>> = vec![Vec::new(); m];
    for &id in ids {
        shards[shard_of(id, m)].push(id);
    }
    shards
}

/// Per-machine work counters for one simulated round, in abstract "work
/// units" (one neighbor-map entry processed, or one per-cluster flag op).
/// Feeds the critical-path time model (`RoundMetrics::t_sim`): each phase
/// is a barrier, so its simulated duration is the *maximum* unit count
/// across machines, divided by the cores available per machine.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardLoad {
    /// Find-reciprocal-NN phase: per-cluster flag evaluations.
    pub find_work: u64,
    /// Merge phase: union-map entries gathered and folded.
    pub merge_work: u64,
    /// Update-NN phase: neighbor entries scanned during rescans.
    pub nn_scan_work: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_stable_and_in_range() {
        for machines in [1usize, 2, 3, 7, 16] {
            for c in 0..200u32 {
                let s = shard_of(c, machines);
                assert!(s < machines);
                assert_eq!(s, shard_of(c, machines), "placement must be pure");
            }
        }
    }

    #[test]
    fn partition_is_total_and_disjoint() {
        let ids: Vec<u32> = (0..57).map(|i| i * 3 + 1).collect();
        let parts = partition(&ids, 5);
        assert_eq!(parts.len(), 5);
        let total: usize = parts.iter().map(Vec::len).sum();
        assert_eq!(total, ids.len());
        for (s, part) in parts.iter().enumerate() {
            for &id in part {
                assert_eq!(shard_of(id, 5), s);
            }
        }
    }

    #[test]
    fn more_machines_than_clusters_leaves_empty_shards() {
        let parts = partition(&[0, 1, 2], 16);
        assert_eq!(parts.len(), 16);
        assert_eq!(parts[0], vec![0]);
        assert_eq!(parts[1], vec![1]);
        assert_eq!(parts[2], vec![2]);
        assert!(parts[3..].iter().all(Vec::is_empty));
    }

    #[test]
    fn empty_input_and_degenerate_machine_count() {
        assert!(partition(&[], 4).iter().all(Vec::is_empty));
        // machines is clamped to 1, never panics.
        assert_eq!(shard_of(9, 0), 0);
        assert_eq!(partition(&[7], 0).len(), 1);
    }

    #[test]
    fn single_machine_owns_everything() {
        let parts = partition(&[5, 9, 100], 1);
        assert_eq!(parts, vec![vec![5, 9, 100]]);
    }
}
