//! The NN-scan and union-map computation shared by the shared-memory
//! engine ([`super::RacEngine`]), the distributed engine
//! ([`crate::dist`]), and the hashmap reference engine
//! ([`super::baseline`]).
//!
//! Given a merging pair `(L, P)` and the two parent neighbor views,
//! compute the neighbor map of `L ∪ P`. Targets that are themselves
//! merging pairs are canonicalised to their pair leader and combined with
//! a second Lance–Williams step (see the deviation note in [`super`]'s
//! docs).
//!
//! ## Backend independence (bitwise)
//!
//! All functions here take neighbor state through the
//! [`NeighborsRef`](crate::store::NeighborsRef) abstraction, whose visit
//! *order* is unspecified — the flat arena store yields row-storage
//! order, the hashmap oracle yields hash order. Every floating-point
//! reduction is therefore arranged so its result is a function of the
//! edge *set* only:
//!
//! * [`scan_nn`] minimises under the total order `(weight, id)`, which is
//!   order-insensitive by construction (Theorem 1 needs this single
//!   total order everywhere).
//! * min/max folds (single/complete linkage) are commutative and
//!   associative, so the single-pass fold may run in any visit order.
//! * Everything else — including **average** linkage — goes through the
//!   gather path, which files each of the up-to-four parent edges toward
//!   a target pair into a *named slot* (`lc/pc/ld/pd`) and reduces the
//!   slots in one fixed expression order. A running-mean fold in visit
//!   order would round differently per backend; the slot reduction makes
//!   the result (and hence the dendrogram) bitwise identical across
//!   stores and thread counts, which `rust/tests/store_equivalence.rs`
//!   asserts.

use rustc_hash::FxHashMap;

use crate::linkage::{EdgeState, Linkage, MergeCtx, Weight};
use crate::store::NeighborsRef;

// The total-order helpers live next to the kernels they pin
// ([`crate::store::scan`]); re-exported here because this module is where
// the engines historically import the NN order from.
pub use crate::store::scan::{cmp_weight_pair, nn_better};

/// Scan a neighbor view for the `(weight, id)`-minimal entry, returning
/// [`super::NO_NN`] for an empty view. Shared by every engine so
/// nearest-neighbor tie-breaking is bitwise identical everywhere.
///
/// Delegates to [`NeighborsRef::nn_min`]: on the flat store that is the
/// dispatched SIMD row kernel ([`crate::store::scan`]), everywhere else
/// the scalar reference fold — bitwise identical either way.
#[inline]
pub fn scan_nn<N: NeighborsRef>(neighbors: N) -> (u32, Weight) {
    neighbors.nn_min()
}

/// What the computation needs to know about any cluster id it encounters
/// as a neighbor: merge status, pair partner, size, and the pair's merge
/// weight. In the shared-memory engine this is a direct state lookup; in
/// the distributed engine it is answered from batched remote responses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PairView {
    pub merging: bool,
    /// Partner id (valid only when `merging`).
    pub partner: u32,
    pub size: u64,
    /// `W(C, partner)` (valid only when `merging`).
    pub pair_weight: Weight,
}

/// Per-target accumulator: the up-to-four parent edges between `{L, P}`
/// and a target pair `{C, D}` (`lc/pc` toward the target's leader `C`,
/// `ld/pd` toward its partner `D`; non-merging targets only use `lc/pc`).
#[derive(Default, Clone, Copy)]
struct Gather {
    lc: Option<EdgeState>,
    pc: Option<EdgeState>,
    ld: Option<EdgeState>,
    pd: Option<EdgeState>,
}

/// Compute the neighbor map of the union `L ∪ P`.
///
/// * `l`, `p` — the merging pair (leader first), with pair weight `w_lp`
///   and sizes `sl`, `sp`.
/// * `l_neighbors`, `p_neighbors` — their current neighbor views.
/// * `view(x)` — cluster info for any neighbor id (see [`PairView`]).
///
/// The result is keyed by *canonical* target ids: non-merging neighbors
/// keep their id; merging neighbor pairs appear once under
/// `min(id, partner)`. Entry order is first-encounter order over
/// `l_neighbors` then `p_neighbors`; the entry *values* are independent
/// of visit order (module docs).
///
/// Dispatches to a single-pass fold for linkages whose pair–pair
/// combination is a commutative flat reduction over the up-to-four parent
/// edges (min / max — §Perf item 5), and to the structured two-step
/// Lance–Williams gather path for everything else: Ward/WPGMA need sizes
/// and pair weights per step, and average needs the gather slots' fixed
/// reduction order for backend-independent rounding (module docs).
#[allow(clippy::too_many_arguments)]
pub fn compute_union_map<N: NeighborsRef>(
    linkage: Linkage,
    l: u32,
    p: u32,
    w_lp: Weight,
    sl: u64,
    sp: u64,
    l_neighbors: N,
    p_neighbors: N,
    view: impl Fn(u32) -> PairView,
) -> Vec<(u32, EdgeState)> {
    match linkage {
        Linkage::Single | Linkage::Complete => {
            compute_union_map_flat(linkage, l, p, l_neighbors, p_neighbors, view)
        }
        _ => compute_union_map_lw(
            linkage,
            l,
            p,
            w_lp,
            sl,
            sp,
            l_neighbors,
            p_neighbors,
            view,
        ),
    }
}

/// Single-pass fold for commutative-associative linkages (min/max):
/// every parent edge toward the canonical target is reduced with
/// [`flat_fold`] as encountered — no gather slots, one output vector.
fn compute_union_map_flat<N: NeighborsRef>(
    linkage: Linkage,
    l: u32,
    p: u32,
    l_neighbors: N,
    p_neighbors: N,
    view: impl Fn(u32) -> PairView,
) -> Vec<(u32, EdgeState)> {
    #[inline]
    fn flat_fold(linkage: Linkage, acc: &mut EdgeState, e: EdgeState) {
        match linkage {
            Linkage::Single => {
                acc.weight = acc.weight.min(e.weight);
                acc.count += e.count;
            }
            Linkage::Complete => {
                acc.weight = acc.weight.max(e.weight);
                acc.count += e.count;
            }
            _ => unreachable!("flat path is only for single/complete"),
        }
    }

    let cap = l_neighbors.live_len() + p_neighbors.live_len();
    let mut out: Vec<(u32, EdgeState)> = Vec::with_capacity(cap);
    let mut index: FxHashMap<u32, u32> =
        FxHashMap::with_capacity_and_hasher(cap, Default::default());
    for map in [l_neighbors, p_neighbors] {
        map.for_each_edge(|x, e| {
            if x == l || x == p {
                return;
            }
            let vx = view(x);
            let t_id = if vx.merging { x.min(vx.partner) } else { x };
            match index.entry(t_id) {
                std::collections::hash_map::Entry::Occupied(slot) => {
                    flat_fold(linkage, &mut out[*slot.get() as usize].1, e);
                }
                std::collections::hash_map::Entry::Vacant(slot) => {
                    slot.insert(out.len() as u32);
                    out.push((t_id, e));
                }
            }
        });
    }
    out
}

/// Structured two-step Lance–Williams gather path (average, Ward, WPGMA,
/// and any future linkage whose update needs per-step sizes/pair weights
/// or a canonical reduction order).
#[allow(clippy::too_many_arguments)]
fn compute_union_map_lw<N: NeighborsRef>(
    linkage: Linkage,
    l: u32,
    p: u32,
    w_lp: Weight,
    sl: u64,
    sp: u64,
    l_neighbors: N,
    p_neighbors: N,
    view: impl Fn(u32) -> PairView,
) -> Vec<(u32, EdgeState)> {
    let cap = l_neighbors.live_len() + p_neighbors.live_len();
    let mut index: FxHashMap<u32, u32> =
        FxHashMap::with_capacity_and_hasher(cap, Default::default());
    let mut slots: Vec<(u32, Gather, PairView)> = Vec::with_capacity(cap);

    for (from_p, map) in [(false, l_neighbors), (true, p_neighbors)] {
        map.for_each_edge(|x, e| {
            if x == l || x == p {
                return;
            }
            let vx = view(x);
            // Canonicalise merging targets to their pair leader (paper
            // pseudocode deviation — see module docs in `super`).
            let (t_id, toward_leader, vt) = if vx.merging {
                let t = x.min(vx.partner);
                if t == x {
                    (t, true, vx)
                } else {
                    (t, false, view(t))
                }
            } else {
                (x, true, vx)
            };
            let i = *index.entry(t_id).or_insert_with(|| {
                slots.push((t_id, Gather::default(), vt));
                slots.len() as u32 - 1
            });
            let g = &mut slots[i as usize].1;
            match (from_p, toward_leader) {
                (false, true) => g.lc = Some(e),
                (true, true) => g.pc = Some(e),
                (false, false) => g.ld = Some(e),
                (true, false) => g.pd = Some(e),
            }
        });
    }

    let mut out: Vec<(u32, EdgeState)> = Vec::with_capacity(slots.len());
    for (t_id, g, vt) in slots {
        // Step 1: (L, P) → U against the target's leader C and partner D.
        let uc = linkage.merge(
            g.lc,
            g.pc,
            MergeCtx {
                size_a: sl,
                size_b: sp,
                size_c: vt.size,
                pair_weight: w_lp,
            },
        );
        let e = if vt.merging {
            // vt is the canonical leader's view; its partner is the
            // higher-id member D of the target pair.
            let vd = view(vt.partner);
            debug_assert!(vt.partner > t_id);
            let ud = linkage.merge(
                g.ld,
                g.pd,
                MergeCtx {
                    size_a: sl,
                    size_b: sp,
                    size_c: vd.size,
                    pair_weight: w_lp,
                },
            );
            // Step 2: W(U, C∪D) from W(U,C), W(U,D): roles A=C, B=D, C=U.
            linkage.merge(
                uc,
                ud,
                MergeCtx {
                    size_a: vt.size,
                    size_b: vd.size,
                    size_c: sl + sp,
                    pair_weight: vt.pair_weight,
                },
            )
        } else {
            uc
        };
        if let Some(e) = e {
            out.push((t_id, e));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn es(w: Weight) -> EdgeState {
        EdgeState::point(w)
    }

    fn get(out: &[(u32, EdgeState)], id: u32) -> EdgeState {
        out.iter()
            .find(|&&(t, _)| t == id)
            .map(|&(_, e)| e)
            .unwrap_or_else(|| panic!("no entry for {id}"))
    }

    #[test]
    fn scan_nn_breaks_ties_by_id() {
        let map: FxHashMap<u32, EdgeState> =
            [(7u32, es(2.0)), (3u32, es(2.0)), (9u32, es(5.0))]
                .into_iter()
                .collect();
        assert_eq!(scan_nn(&map), (3, 2.0));
        let empty = FxHashMap::default();
        assert_eq!(scan_nn(&empty), (crate::rac::NO_NN, Weight::INFINITY));
    }

    #[test]
    fn union_of_disjoint_neighbor_sets() {
        // L neighbors {2}, P neighbors {3}; neither 2 nor 3 merging.
        let mut ln = FxHashMap::default();
        ln.insert(1u32, es(1.0)); // edge to partner (skipped)
        ln.insert(2u32, es(5.0));
        let mut pn = FxHashMap::default();
        pn.insert(0u32, es(1.0));
        pn.insert(3u32, es(7.0));
        let view = |x: u32| PairView {
            merging: false,
            partner: x,
            size: 1,
            pair_weight: 0.0,
        };
        let out = compute_union_map(Linkage::Average, 0, 1, 1.0, 1, 1, &ln, &pn, view);
        assert_eq!(out.len(), 2);
        assert_eq!(get(&out, 2).weight, 5.0);
        assert_eq!(get(&out, 3).weight, 7.0);
    }

    #[test]
    fn merging_target_combined_under_leader() {
        // Pairs (0,1) and (2,3); all four cross edges exist.
        let mut ln = FxHashMap::default();
        ln.insert(1u32, es(1.0));
        ln.insert(2u32, es(4.0));
        ln.insert(3u32, es(6.0));
        let mut pn = FxHashMap::default();
        pn.insert(0u32, es(1.0));
        pn.insert(2u32, es(8.0));
        pn.insert(3u32, es(10.0));
        let view = |x: u32| match x {
            2 => PairView {
                merging: true,
                partner: 3,
                size: 1,
                pair_weight: 2.0,
            },
            3 => PairView {
                merging: true,
                partner: 2,
                size: 1,
                pair_weight: 2.0,
            },
            _ => unreachable!(),
        };
        let out = compute_union_map(Linkage::Average, 0, 1, 1.0, 1, 1, &ln, &pn, view);
        assert_eq!(out.len(), 1);
        // Average over all 4 point pairs: (4+8+6+10)/4 = 7.
        assert!((get(&out, 2).weight - 7.0).abs() < 1e-12);
        assert_eq!(get(&out, 2).count, 4);
    }

    #[test]
    fn bridge_via_non_leaders_only() {
        // Pairs (0,1), (2,3); only edge P(=1)–D(=3). Union edge must exist
        // under canonical key 2.
        let ln: FxHashMap<u32, EdgeState> = [(1u32, es(1.0))].into_iter().collect();
        let pn: FxHashMap<u32, EdgeState> =
            [(0u32, es(1.0)), (3u32, es(9.0))].into_iter().collect();
        let view = |x: u32| match x {
            2 => PairView {
                merging: true,
                partner: 3,
                size: 1,
                pair_weight: 2.0,
            },
            3 => PairView {
                merging: true,
                partner: 2,
                size: 1,
                pair_weight: 2.0,
            },
            _ => unreachable!("view({x})"),
        };
        let out = compute_union_map(Linkage::Single, 0, 1, 1.0, 1, 1, &ln, &pn, view);
        assert_eq!(out.len(), 1);
        assert_eq!(get(&out, 2).weight, 9.0);
    }

    /// The same edge set presented through the flat store and through a
    /// hashmap must produce bitwise-identical union values — the backend
    /// independence contract of the module docs.
    #[test]
    fn backends_agree_bitwise() {
        use crate::graph::Graph;
        use crate::store::NeighborStore;

        // Pair (0,1) merging with a merging neighbor pair (2,3) plus two
        // plain neighbors 4, 5 — exercises every gather slot.
        let g = Graph::from_edges(
            6,
            [
                (0, 1, 1.0),
                (0, 2, 4.0),
                (0, 3, 6.0),
                (1, 2, 8.0),
                (1, 3, 10.0),
                (0, 4, 3.0),
                (1, 5, 2.0),
                (2, 3, 1.5),
            ],
        );
        let store = NeighborStore::from_graph(&g);
        let ln: FxHashMap<u32, EdgeState> =
            g.neighbors(0).map(|(v, w)| (v, es(w))).collect();
        let pn: FxHashMap<u32, EdgeState> =
            g.neighbors(1).map(|(v, w)| (v, es(w))).collect();
        let view = |x: u32| match x {
            2 | 3 => PairView {
                merging: true,
                partner: 5 - x,
                size: 1,
                pair_weight: 1.5,
            },
            _ => PairView {
                merging: false,
                partner: x,
                size: 1,
                pair_weight: 0.0,
            },
        };
        for linkage in [Linkage::Single, Linkage::Complete, Linkage::Average] {
            let flat = compute_union_map(
                linkage,
                0,
                1,
                1.0,
                1,
                1,
                store.row(0),
                store.row(1),
                view,
            );
            let hash = compute_union_map(linkage, 0, 1, 1.0, 1, 1, &ln, &pn, view);
            let key = |out: &[(u32, EdgeState)]| {
                let mut v: Vec<(u32, u64, u64)> = out
                    .iter()
                    .map(|&(t, e)| (t, e.weight.to_bits(), e.count))
                    .collect();
                v.sort_unstable();
                v
            };
            assert_eq!(key(&flat), key(&hash), "{linkage:?}");
        }
    }
}
