//! The union-map computation shared by the shared-memory engine
//! ([`super::RacEngine`]) and the distributed engine ([`crate::dist`]).
//!
//! Given a merging pair `(L, P)` and the two parent neighbor maps, compute
//! the neighbor map of `L ∪ P`. Targets that are themselves merging pairs
//! are canonicalised to their pair leader and combined with a second
//! Lance–Williams step (see the deviation note in [`super`]'s docs).

use rustc_hash::FxHashMap;

use crate::linkage::{EdgeState, Linkage, MergeCtx, Weight};

/// Scan a neighbor map for the `(weight, id)`-minimal entry, returning
/// [`super::NO_NN`] for an empty map. Shared by the shared-memory and
/// distributed engines so nearest-neighbor tie-breaking is bitwise
/// identical everywhere (Theorem 1 needs a single total order).
#[inline]
pub fn scan_nn(map: &FxHashMap<u32, EdgeState>) -> (u32, Weight) {
    let mut best = (super::NO_NN, Weight::INFINITY);
    for (&v, e) in map {
        if e.weight < best.1 || (e.weight == best.1 && v < best.0) {
            best = (v, e.weight);
        }
    }
    best
}

/// What the computation needs to know about any cluster id it encounters
/// as a neighbor: merge status, pair partner, size, and the pair's merge
/// weight. In the shared-memory engine this is a direct state lookup; in
/// the distributed engine it is answered from batched remote responses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PairView {
    pub merging: bool,
    /// Partner id (valid only when `merging`).
    pub partner: u32,
    pub size: u64,
    /// `W(C, partner)` (valid only when `merging`).
    pub pair_weight: Weight,
}

/// Per-target accumulator: the up-to-four parent edges between `{L, P}`
/// and a target pair `{C, D}` (`lc/pc` toward the target's leader `C`,
/// `ld/pd` toward its partner `D`; non-merging targets only use `lc/pc`).
#[derive(Default, Clone, Copy)]
struct Gather {
    lc: Option<EdgeState>,
    pc: Option<EdgeState>,
    ld: Option<EdgeState>,
    pd: Option<EdgeState>,
}

/// Compute the neighbor map of the union `L ∪ P`.
///
/// * `l`, `p` — the merging pair (leader first), with pair weight `w_lp`
///   and sizes `sl`, `sp`.
/// * `l_neighbors`, `p_neighbors` — their current neighbor maps.
/// * `view(x)` — cluster info for any neighbor id (see [`PairView`]).
///
/// The result is keyed by *canonical* target ids: non-merging neighbors
/// keep their id; merging neighbor pairs appear once under
/// `min(id, partner)`.
///
/// Dispatches to a single-pass fold for linkages whose pair–pair
/// combination is a flat associative reduction over the up-to-four parent
/// edges (min / max / count-weighted mean — §Perf item 5), and to the
/// structured two-step Lance–Williams path for Ward/WPGMA, whose updates
/// need sizes and pair weights per step.
pub fn compute_union_map(
    linkage: Linkage,
    l: u32,
    p: u32,
    w_lp: Weight,
    sl: u64,
    sp: u64,
    l_neighbors: &FxHashMap<u32, EdgeState>,
    p_neighbors: &FxHashMap<u32, EdgeState>,
    view: impl Fn(u32) -> PairView,
) -> FxHashMap<u32, EdgeState> {
    match linkage {
        Linkage::Single | Linkage::Complete | Linkage::Average => {
            compute_union_map_flat(linkage, l, p, l_neighbors, p_neighbors, view)
        }
        _ => compute_union_map_lw(
            linkage,
            l,
            p,
            w_lp,
            sl,
            sp,
            l_neighbors,
            p_neighbors,
            view,
        ),
    }
}

/// Single-pass fold for fully-associative linkages: every parent edge
/// toward the canonical target is reduced with [`flat_fold`] as
/// encountered — no gather map, one output hashmap.
fn compute_union_map_flat(
    linkage: Linkage,
    l: u32,
    p: u32,
    l_neighbors: &FxHashMap<u32, EdgeState>,
    p_neighbors: &FxHashMap<u32, EdgeState>,
    view: impl Fn(u32) -> PairView,
) -> FxHashMap<u32, EdgeState> {
    #[inline]
    fn flat_fold(linkage: Linkage, acc: &mut EdgeState, e: EdgeState) {
        match linkage {
            Linkage::Single => {
                acc.weight = acc.weight.min(e.weight);
                acc.count += e.count;
            }
            Linkage::Complete => {
                acc.weight = acc.weight.max(e.weight);
                acc.count += e.count;
            }
            Linkage::Average => {
                let total = acc.count + e.count;
                acc.weight = (acc.weight * acc.count as Weight
                    + e.weight * e.count as Weight)
                    / total as Weight;
                acc.count = total;
            }
            _ => unreachable!("flat path is only for single/complete/average"),
        }
    }

    let mut out: FxHashMap<u32, EdgeState> = FxHashMap::with_capacity_and_hasher(
        l_neighbors.len() + p_neighbors.len(),
        Default::default(),
    );
    for map in [l_neighbors, p_neighbors] {
        for (&x, &e) in map {
            if x == l || x == p {
                continue;
            }
            let vx = view(x);
            let t_id = if vx.merging { x.min(vx.partner) } else { x };
            out.entry(t_id)
                .and_modify(|acc| flat_fold(linkage, acc, e))
                .or_insert(e);
        }
    }
    out
}

/// Structured two-step Lance–Williams path (Ward, WPGMA, and any future
/// linkage whose update needs per-step sizes/pair weights).
#[allow(clippy::too_many_arguments)]
fn compute_union_map_lw(
    linkage: Linkage,
    l: u32,
    p: u32,
    w_lp: Weight,
    sl: u64,
    sp: u64,
    l_neighbors: &FxHashMap<u32, EdgeState>,
    p_neighbors: &FxHashMap<u32, EdgeState>,
    view: impl Fn(u32) -> PairView,
) -> FxHashMap<u32, EdgeState> {
    let cap = l_neighbors.len() + p_neighbors.len();
    let mut gather: FxHashMap<u32, (Gather, PairView)> =
        FxHashMap::with_capacity_and_hasher(cap, Default::default());

    for (from_p, map) in [(false, l_neighbors), (true, p_neighbors)] {
        for (&x, &e) in map {
            if x == l || x == p {
                continue;
            }
            let vx = view(x);
            // Canonicalise merging targets to their pair leader (paper
            // pseudocode deviation — see module docs).
            let (t_id, toward_leader, vt) = if vx.merging {
                let t = x.min(vx.partner);
                if t == x {
                    (t, true, vx)
                } else {
                    (t, false, view(t))
                }
            } else {
                (x, true, vx)
            };
            let slot = gather.entry(t_id).or_insert((Gather::default(), vt));
            match (from_p, toward_leader) {
                (false, true) => slot.0.lc = Some(e),
                (true, true) => slot.0.pc = Some(e),
                (false, false) => slot.0.ld = Some(e),
                (true, false) => slot.0.pd = Some(e),
            }
        }
    }

    let mut out: FxHashMap<u32, EdgeState> =
        FxHashMap::with_capacity_and_hasher(gather.len(), Default::default());
    for (t_id, (g, vt)) in gather {
        // Step 1: (L, P) → U against the target's leader C and partner D.
        let uc = linkage.merge(
            g.lc,
            g.pc,
            MergeCtx {
                size_a: sl,
                size_b: sp,
                size_c: vt.size,
                pair_weight: w_lp,
            },
        );
        let e = if vt.merging {
            // vt is the canonical leader's view; its partner is the
            // higher-id member D of the target pair.
            let vd = view(vt.partner);
            debug_assert!(vt.partner > t_id);
            let ud = linkage.merge(
                g.ld,
                g.pd,
                MergeCtx {
                    size_a: sl,
                    size_b: sp,
                    size_c: vd.size,
                    pair_weight: w_lp,
                },
            );
            // Step 2: W(U, C∪D) from W(U,C), W(U,D): roles A=C, B=D, C=U.
            linkage.merge(
                uc,
                ud,
                MergeCtx {
                    size_a: vt.size,
                    size_b: vd.size,
                    size_c: sl + sp,
                    pair_weight: vt.pair_weight,
                },
            )
        } else {
            uc
        };
        if let Some(e) = e {
            out.insert(t_id, e);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn es(w: Weight) -> EdgeState {
        EdgeState::point(w)
    }

    #[test]
    fn union_of_disjoint_neighbor_sets() {
        // L neighbors {2}, P neighbors {3}; neither 2 nor 3 merging.
        let mut ln = FxHashMap::default();
        ln.insert(1u32, es(1.0)); // edge to partner (skipped)
        ln.insert(2u32, es(5.0));
        let mut pn = FxHashMap::default();
        pn.insert(0u32, es(1.0));
        pn.insert(3u32, es(7.0));
        let view = |x: u32| PairView {
            merging: false,
            partner: x,
            size: 1,
            pair_weight: 0.0,
        };
        let out = compute_union_map(Linkage::Average, 0, 1, 1.0, 1, 1, &ln, &pn, view);
        assert_eq!(out.len(), 2);
        assert_eq!(out[&2].weight, 5.0);
        assert_eq!(out[&3].weight, 7.0);
    }

    #[test]
    fn merging_target_combined_under_leader() {
        // Pairs (0,1) and (2,3); all four cross edges exist.
        let mut ln = FxHashMap::default();
        ln.insert(1u32, es(1.0));
        ln.insert(2u32, es(4.0));
        ln.insert(3u32, es(6.0));
        let mut pn = FxHashMap::default();
        pn.insert(0u32, es(1.0));
        pn.insert(2u32, es(8.0));
        pn.insert(3u32, es(10.0));
        let view = |x: u32| match x {
            2 => PairView {
                merging: true,
                partner: 3,
                size: 1,
                pair_weight: 2.0,
            },
            3 => PairView {
                merging: true,
                partner: 2,
                size: 1,
                pair_weight: 2.0,
            },
            _ => unreachable!(),
        };
        let out = compute_union_map(Linkage::Average, 0, 1, 1.0, 1, 1, &ln, &pn, view);
        assert_eq!(out.len(), 1);
        // Average over all 4 point pairs: (4+8+6+10)/4 = 7.
        assert!((out[&2].weight - 7.0).abs() < 1e-12);
        assert_eq!(out[&2].count, 4);
    }

    #[test]
    fn bridge_via_non_leaders_only() {
        // Pairs (0,1), (2,3); only edge P(=1)–D(=3). Union edge must exist
        // under canonical key 2.
        let ln: FxHashMap<u32, EdgeState> = [(1u32, es(1.0))].into_iter().collect();
        let pn: FxHashMap<u32, EdgeState> =
            [(0u32, es(1.0)), (3u32, es(9.0))].into_iter().collect();
        let view = |x: u32| match x {
            2 => PairView {
                merging: true,
                partner: 3,
                size: 1,
                pair_weight: 2.0,
            },
            3 => PairView {
                merging: true,
                partner: 2,
                size: 1,
                pair_weight: 2.0,
            },
            _ => unreachable!("view({x})"),
        };
        let out = compute_union_map(Linkage::Single, 0, 1, 1.0, 1, 1, &ln, &pn, view);
        assert_eq!(out.len(), 1);
        assert_eq!(out[&2].weight, 9.0);
    }
}
