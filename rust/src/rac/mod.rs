//! Reciprocal Agglomerative Clustering — the paper's Algorithm 2 and the
//! detailed implementation of §5, as a shared-memory round engine over
//! the flat arena-backed neighbor store ([`crate::store`]).
//!
//! Each round runs three phases, all parallelised across clusters:
//!
//! 1. **Find Reciprocal Nearest Neighbors** — `C.will_merge = (C.nn.nn == C)`;
//!    the lower-id member of each pair is the *leader* and owns the merge.
//! 2. **Update Cluster Dissimilarities** — two sub-steps:
//!    * *Compute*: every leader independently computes the neighbor map
//!      of its union (read-only over shared state). When a neighbor is
//!      itself a merging pair, the pair–pair dissimilarity `W(A∪B, C∪D)`
//!      is computed *twice* (once by each leader) rather than
//!      coordinated — the paper's contention-free choice.
//!    * *Apply*: the computed unions are applied by an **owner-sharded
//!      parallel pass** ([`crate::store::NeighborStore::par_apply_round`])
//!      with no locks: worker `w` of `S` shards exclusively owns every
//!      cluster id with `id % S == w`, and handles exactly the union-row
//!      installs, partner retirements, and neighbor patches that land on
//!      its rows. Because adjacency is symmetric, a patch never grows a
//!      row (it overwrites the leader's slot or reuses the retired
//!      partner's), so workers write strictly disjoint memory and the
//!      result is bit-for-bit identical for every thread count.
//! 3. **Update Nearest Neighbors** — any cluster that merged, or whose
//!    cached nearest neighbor merged, rescans its neighbor row. For
//!    reducible linkages no other cluster's NN can change (a merge never
//!    moves the union closer than the closest parent), so the rescan set is
//!    exactly the paper's `C.will_merge or C.nn.will_merge` condition.
//!
//! After the apply pass the store compacts itself when dead arena space
//! outgrows live entries (policy in [`crate::store`]'s docs).
//!
//! ## Deviation from the paper's pseudocode (documented)
//!
//! The §5 "Update Cluster Dissimilarities" pseudocode skips neighbors that
//! are merging but are not the lower-id leader of their own pair. If the
//! only edge between two merging pairs `(A,B)` and `(C,D)` connects the two
//! *non-leaders* (`B–D`), a literal reading drops the edge between the two
//! unions entirely, which breaks exactness on sparse graphs. We instead
//! **canonicalise** every merging neighbor to its pair leader
//! (`min(id, nn.id)`) and aggregate the up-to-four underlying parent edges
//! per target pair. Theorem-1 property tests (`rust/tests/`) verify
//! exactness against sequential HAC.
//!
//! The round loop itself — init scan, phase-2 compute/apply, phase-3
//! rescan, metrics, termination — is the engine-shared
//! [`crate::engine::RoundDriver`]; this engine is the driver instantiated
//! with the flat [`NeighborStore`] and the exact reciprocal-NN phase-1
//! selector ([`crate::engine::RnnSelector`]). The distributed version of
//! the same phases (sharded state, batched cross-machine messages) lives
//! in [`crate::dist`]. The PR-1 hashmap-backed engine survives as
//! [`baseline::HashRacEngine`] — the differential oracle and perf
//! baseline for the flat store (`rust/tests/store_equivalence.rs`,
//! `benches/hot_paths.rs`).

pub mod baseline;
pub mod logic;

use crate::dendrogram::Dendrogram;
use crate::engine::{RnnSelector, RoundDriver};
use crate::graph::Graph;
use crate::linkage::Linkage;
use crate::metrics::RunMetrics;
use crate::store::NeighborStore;
use crate::trace::TraceSink;

/// Sentinel "no nearest neighbor" (isolated cluster). Canonically
/// defined next to the scan kernels whose accumulators start from it.
pub use crate::store::scan::NO_NN;

/// Result of a clustering run.
#[derive(Debug)]
pub struct RacResult {
    pub dendrogram: Dendrogram,
    pub metrics: RunMetrics,
}

/// Shared-memory RAC engine over the flat neighbor store.
pub struct RacEngine {
    driver: RoundDriver<NeighborStore>,
}

impl RacEngine {
    /// Build an engine over a dissimilarity graph.
    ///
    /// # Panics
    /// If the linkage is not reducible (Theorem 1 does not apply — use
    /// [`RacEngine::new_unchecked`] to observe the failure mode), or if a
    /// complete-graph-only linkage is given a sparse graph.
    pub fn new(g: &Graph, linkage: Linkage) -> Self {
        assert!(
            linkage.is_reducible(),
            "RAC is exact only for reducible linkages (Theorem 1); \
             use new_unchecked to experiment"
        );
        Self::new_unchecked(g, linkage)
    }

    /// Build without the reducibility guard (for demonstrating where
    /// Theorem 1's hypothesis is necessary).
    ///
    /// Neighbor rows are pre-sized exactly from the graph's CSR degrees
    /// ([`NeighborStore::from_graph`]) — one arena allocation, no
    /// per-insert growth.
    pub fn new_unchecked(g: &Graph, linkage: Linkage) -> Self {
        if !linkage.supports_sparse() {
            let n = g.n();
            assert!(
                g.m() == n * (n - 1) / 2,
                "{linkage:?} linkage requires a complete graph"
            );
        }
        let n = g.n();
        RacEngine {
            driver: RoundDriver::new(NeighborStore::from_graph(g), n, linkage),
        }
    }

    /// Limit the worker-thread count (the paper's CPUs knob, Fig 3c).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.driver.set_threads(threads);
        self
    }

    /// Override the round safety cap.
    pub fn with_max_rounds(mut self, max_rounds: usize) -> Self {
        self.driver.set_max_rounds(max_rounds);
        self
    }

    /// Stream structured trace events into `sink` (see [`crate::trace`]).
    /// Tracing is purely observational: the dendrogram is bitwise
    /// identical with or without it.
    pub fn with_trace(mut self, sink: &TraceSink) -> Self {
        self.driver.set_trace(sink.clone(), "rac");
        self
    }

    /// Run RAC to completion; returns the dendrogram and per-round metrics.
    pub fn run(self) -> RacResult {
        let r = self.driver.run(&mut RnnSelector);
        RacResult {
            dendrogram: r.dendrogram,
            metrics: r.metrics,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::hac::naive_hac;

    #[test]
    fn two_points() {
        let g = Graph::from_edges(2, [(0, 1, 3.5)]);
        let r = RacEngine::new(&g, Linkage::Average).run();
        assert_eq!(r.dendrogram.merges().len(), 1);
        assert_eq!(r.dendrogram.merges()[0].weight, 3.5);
        assert_eq!(r.metrics.merge_rounds(), 1);
    }

    #[test]
    fn matches_hac_on_grid() {
        let g = data::grid1d_graph(200, 17);
        for l in Linkage::SPARSE_REDUCIBLE {
            let hac = naive_hac(&g, l);
            let rac = RacEngine::new(&g, l).run();
            assert!(
                hac.same_clustering(&rac.dendrogram, 1e-9),
                "{l:?} diverged from HAC"
            );
        }
    }

    #[test]
    fn matches_hac_on_complete_graph() {
        let g = data::stable_hierarchy(4, 4.0, 23);
        for l in [
            Linkage::Single,
            Linkage::Complete,
            Linkage::Average,
            Linkage::WeightedAverage,
            Linkage::Ward,
        ] {
            let hac = naive_hac(&g, l);
            let rac = RacEngine::new(&g, l).run();
            assert!(
                hac.same_clustering(&rac.dendrogram, 1e-6),
                "{l:?} diverged from HAC"
            );
        }
    }

    #[test]
    fn cross_pair_edge_between_non_leaders() {
        // Two reciprocal pairs (0,1) and (2,3) whose ONLY connection is the
        // edge 1–3 (both non-leaders): the canonicalisation fix must carry
        // it to the union edge, or the graph falls apart (see module docs).
        let g = Graph::from_edges(
            4,
            [
                (0, 1, 1.0),
                (2, 3, 1.5),
                (1, 3, 10.0),
            ],
        );
        let hac = naive_hac(&g, Linkage::Average);
        let rac = RacEngine::new(&g, Linkage::Average).run();
        assert_eq!(rac.dendrogram.merges().len(), 3, "lost the bridge edge");
        assert!(hac.same_clustering(&rac.dendrogram, 1e-9));
    }

    #[test]
    fn parallel_pairs_merge_in_one_round() {
        // 4 well-separated tight pairs → round 1 merges all 4 at once.
        let mut edges = vec![];
        for i in 0..4u32 {
            edges.push((2 * i, 2 * i + 1, 1.0 + i as f64 * 0.01));
        }
        for i in 0..3u32 {
            edges.push((2 * i, 2 * (i + 1), 100.0 + i as f64));
        }
        let g = Graph::from_edges(8, edges);
        let r = RacEngine::new(&g, Linkage::Average).run();
        assert_eq!(r.metrics.rounds[0].merges, 4);
        assert!((r.metrics.rounds[0].alpha() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn disconnected_components() {
        let g = Graph::from_edges(6, [(0, 1, 1.0), (2, 3, 1.0), (3, 4, 2.0)]);
        let r = RacEngine::new(&g, Linkage::Single).run();
        assert_eq!(r.dendrogram.merges().len(), 3);
        assert_eq!(r.dendrogram.remaining_clusters(), 3); // {0,1}, {2,3,4}, {5}
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let g = data::grid1d_graph(300, 5);
        let base = RacEngine::new(&g, Linkage::Average).with_threads(1).run();
        for t in [2, 4, 8] {
            let r = RacEngine::new(&g, Linkage::Average).with_threads(t).run();
            assert!(base.dendrogram.same_clustering(&r.dendrogram, 1e-12));
        }
    }

    #[test]
    fn metrics_account_every_merge() {
        let g = data::grid1d_graph(128, 3);
        let r = RacEngine::new(&g, Linkage::Average).run();
        assert_eq!(r.metrics.total_merges(), 127);
        assert_eq!(r.metrics.total_merges(), r.dendrogram.merges().len());
        // Paper Fig 2: early rounds have lots of parallelism.
        assert!(r.metrics.rounds[0].merges > 10);
    }

    #[test]
    #[should_panic(expected = "reducible")]
    fn rejects_centroid_by_default() {
        let g = data::stable_hierarchy(2, 4.0, 0);
        RacEngine::new(&g, Linkage::Centroid);
    }

    #[test]
    fn empty_and_singleton() {
        let r = RacEngine::new(&Graph::from_edges(0, []), Linkage::Average).run();
        assert!(r.dendrogram.merges().is_empty());
        let r = RacEngine::new(&Graph::from_edges(1, []), Linkage::Average).run();
        assert!(r.dendrogram.merges().is_empty());
    }

    /// A workload big enough to push the arena past the compaction
    /// threshold and churn most of it dead: the flat engine must still
    /// track the hashmap oracle bitwise.
    #[test]
    fn compaction_does_not_change_result() {
        let g = data::grid1d_graph(1200, 3);
        for l in [Linkage::Single, Linkage::Average] {
            let flat = RacEngine::new(&g, l).with_threads(4).run();
            let hash = baseline::HashRacEngine::new(&g, l).with_threads(4).run();
            assert_eq!(
                flat.dendrogram.bitwise_merges(),
                hash.dendrogram.bitwise_merges(),
                "{l:?}"
            );
        }
    }
}
