//! The PR-1 hashmap-backed RAC engine, preserved verbatim in behavior as
//! [`HashRacEngine`].
//!
//! Kept for two jobs (and only those — production callers use
//! [`super::RacEngine`]):
//!
//! * **Differential oracle** — `rust/tests/store_equivalence.rs` asserts
//!   the flat-store engine's dendrogram is bitwise identical to this
//!   engine's on random sparse graphs, for every `SPARSE_REDUCIBLE`
//!   linkage and across thread counts. Both engines share
//!   [`super::logic`], so any divergence isolates a bug in the store
//!   layer itself.
//! * **Perf baseline** — `benches/hot_paths.rs` reports this engine next
//!   to the flat-store engine so `BENCH_hot_paths.json` carries the
//!   hashmap-vs-arena trajectory from the first datapoint onward.
//!
//! Differences from the flat engine: cluster adjacency is one
//! `FxHashMap<u32, EdgeState>` per cluster, and the phase-2 apply is the
//! original serial loop (the hashmap layout has no owner-sharded
//! disjoint-write story). Phase 1/2-compute/3 use the same `Pool`
//! parallelism as PR 1.

use std::time::Instant;

use rustc_hash::FxHashMap;

use crate::dendrogram::{Dendrogram, Merge};
use crate::graph::Graph;
use crate::linkage::{EdgeState, Linkage, Weight};
use crate::metrics::{RoundMetrics, RunMetrics};
use crate::util::parallel::default_threads;
use crate::util::pool::Pool;

use super::logic::{compute_union_map, scan_nn, PairView};
use super::{RacResult, NO_NN};

/// Hashmap-backed shared-memory RAC engine (PR-1 baseline; see module
/// docs for why it is retained).
pub struct HashRacEngine {
    linkage: Linkage,
    n: usize,
    active: Vec<bool>,
    active_ids: Vec<u32>,
    size: Vec<u64>,
    nn: Vec<u32>,
    nn_weight: Vec<Weight>,
    will_merge: Vec<bool>,
    neighbors: Vec<FxHashMap<u32, EdgeState>>,
    threads: usize,
    max_rounds: usize,
}

impl HashRacEngine {
    /// Build an engine over a dissimilarity graph (same guards as
    /// [`super::RacEngine::new`]).
    pub fn new(g: &Graph, linkage: Linkage) -> Self {
        assert!(
            linkage.is_reducible(),
            "RAC is exact only for reducible linkages (Theorem 1)"
        );
        if !linkage.supports_sparse() {
            let n = g.n();
            assert!(
                g.m() == n * (n - 1) / 2,
                "{linkage:?} linkage requires a complete graph"
            );
        }
        let n = g.n();
        let neighbors: Vec<FxHashMap<u32, EdgeState>> = (0..n as u32)
            .map(|u| {
                g.neighbors(u)
                    .map(|(v, w)| (v, EdgeState::point(w)))
                    .collect()
            })
            .collect();
        HashRacEngine {
            linkage,
            n,
            active: vec![true; n],
            active_ids: (0..n as u32).collect(),
            size: vec![1; n],
            nn: vec![NO_NN; n],
            nn_weight: vec![Weight::INFINITY; n],
            will_merge: vec![false; n],
            neighbors,
            threads: default_threads(),
            max_rounds: 4 * n + 64,
        }
    }

    /// Limit the worker-thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Run to completion.
    pub fn run(mut self) -> RacResult {
        let pool = Pool::new(self.threads);
        let t0 = Instant::now();
        let mut merges: Vec<Merge> = Vec::with_capacity(self.n.saturating_sub(1));
        let mut metrics = RunMetrics::default();

        let init: Vec<(u32, Weight)> =
            pool.par_map_indexed(self.n, |c| scan_nn(&self.neighbors[c]));
        for (c, (nn, w)) in init.into_iter().enumerate() {
            self.nn[c] = nn;
            self.nn_weight[c] = w;
        }

        let mut n_active = self.n;
        for round in 0..self.max_rounds {
            let mut rm = RoundMetrics {
                round,
                clusters: n_active,
                ..Default::default()
            };

            // Phase 1: find reciprocal nearest neighbors.
            let t = Instant::now();
            let flags = pool.par_map(&self.active_ids, |&c| {
                let c = c as usize;
                self.nn[c] != NO_NN && self.nn[self.nn[c] as usize] == c as u32
            });
            for (&c, flag) in self.active_ids.iter().zip(flags) {
                self.will_merge[c as usize] = flag;
            }
            let leaders: Vec<u32> = self
                .active_ids
                .iter()
                .copied()
                .filter(|&c| self.will_merge[c as usize] && c < self.nn[c as usize])
                .collect();
            rm.t_find = t.elapsed();
            rm.merges = leaders.len();

            if leaders.is_empty() {
                metrics.rounds.push(rm);
                break;
            }

            // Phase 2: parallel union compute, serial apply (the PR-1
            // critical section this baseline exists to measure).
            let t = Instant::now();
            let unions: Vec<crate::store::UnionRow> =
                pool.par_map(&leaders, |&l| (l, self.union_map(l)));

            for &l in &leaders {
                let p = self.nn[l as usize];
                merges.push(Merge {
                    a: l,
                    b: p,
                    weight: self.nn_weight[l as usize],
                });
            }
            for (l, map) in unions {
                let p = self.nn[l as usize];
                for &(t_id, e) in &map {
                    if !self.will_merge[t_id as usize] {
                        let tm = &mut self.neighbors[t_id as usize];
                        tm.remove(&p);
                        tm.insert(l, e);
                    }
                }
                self.size[l as usize] += self.size[p as usize];
                self.neighbors[l as usize] = map.into_iter().collect();
                self.neighbors[p as usize] = FxHashMap::default();
                self.active[p as usize] = false;
            }
            n_active -= rm.merges;
            self.active_ids.retain(|&c| self.active[c as usize]);
            rm.t_merge = t.elapsed();

            // Phase 3: update nearest neighbors.
            let t = Instant::now();
            let updates: Vec<(u32, u32, Weight, usize)> = {
                let ids = &self.active_ids;
                pool.par_filter_map_indexed(ids.len(), |idx| {
                    let c = ids[idx] as usize;
                    let needs_rescan = self.will_merge[c]
                        || (self.nn[c] != NO_NN && self.will_merge[self.nn[c] as usize]);
                    needs_rescan.then(|| {
                        let (nn, w) = scan_nn(&self.neighbors[c]);
                        (c as u32, nn, w, self.neighbors[c].len())
                    })
                })
            };
            rm.nn_updates = updates.len();
            for (c, nn, w, scanned) in updates {
                self.nn[c as usize] = nn;
                self.nn_weight[c as usize] = w;
                rm.nn_scan_entries += scanned;
            }
            rm.t_update_nn = t.elapsed();
            metrics.rounds.push(rm);

            if n_active <= 1 {
                break;
            }
        }

        metrics.total_time = t0.elapsed();
        RacResult {
            dendrogram: Dendrogram::new(self.n, merges),
            metrics,
        }
    }

    fn union_map(&self, l: u32) -> Vec<(u32, EdgeState)> {
        let p = self.nn[l as usize];
        compute_union_map(
            self.linkage,
            l,
            p,
            self.nn_weight[l as usize],
            self.size[l as usize],
            self.size[p as usize],
            &self.neighbors[l as usize],
            &self.neighbors[p as usize],
            |x| PairView {
                merging: self.will_merge[x as usize],
                partner: self.nn[x as usize],
                size: self.size[x as usize],
                pair_weight: self.nn_weight[x as usize],
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    #[test]
    fn two_points() {
        let g = Graph::from_edges(2, [(0, 1, 3.5)]);
        let r = HashRacEngine::new(&g, Linkage::Average).run();
        assert_eq!(r.dendrogram.merges().len(), 1);
        assert_eq!(r.dendrogram.merges()[0].weight, 3.5);
    }

    #[test]
    fn matches_flat_engine_bitwise() {
        let g = data::grid1d_graph(250, 9);
        for l in Linkage::SPARSE_REDUCIBLE {
            let hash = HashRacEngine::new(&g, l).with_threads(2).run();
            let flat = super::super::RacEngine::new(&g, l).with_threads(2).run();
            assert_eq!(
                hash.dendrogram.bitwise_merges(),
                flat.dendrogram.bitwise_merges(),
                "{l:?}"
            );
        }
    }
}
