//! The PR-1 hashmap-backed RAC engine, preserved in behavior as
//! [`HashRacEngine`].
//!
//! Kept for two jobs (and only those — production callers use
//! [`super::RacEngine`]):
//!
//! * **Differential oracle** — `rust/tests/store_equivalence.rs` asserts
//!   that every flat-store engine's dendrogram is bitwise identical to
//!   this engine's on random sparse graphs, for every `SPARSE_REDUCIBLE`
//!   linkage and across thread counts. All engines share the
//!   [`crate::engine::RoundDriver`] loop and [`super::logic`] arithmetic,
//!   so any divergence isolates a bug in the store layer itself.
//! * **Perf baseline** — `benches/hot_paths.rs` reports this engine next
//!   to the flat-store engine so `BENCH_hot_paths.json` carries the
//!   hashmap-vs-arena trajectory from the first datapoint onward.
//!
//! The difference from the flat engine is exactly one driver parameter:
//! the [`HashStore`] backend keeps one `FxHashMap<u32, EdgeState>` per
//! cluster and applies merge rounds with the original serial loop (the
//! hashmap layout has no owner-sharded disjoint-write story). Phase
//! 1/2-compute/3 use the same `Pool` parallelism as every driver engine.

use rustc_hash::FxHashMap;

use crate::engine::{EngineStore, RnnSelector, RoundDriver};
use crate::graph::Graph;
use crate::linkage::{EdgeState, Linkage};
use crate::store::UnionRow;
use crate::util::pool::Pool;

use super::RacResult;

/// Hashmap cluster-adjacency backend (the PR-1 representation): one
/// `FxHashMap` per cluster, serial round application.
pub struct HashStore {
    maps: Vec<FxHashMap<u32, EdgeState>>,
}

impl HashStore {
    /// Build from a graph, one map per node.
    pub fn from_graph(g: &Graph) -> HashStore {
        HashStore {
            maps: (0..g.n() as u32)
                .map(|u| {
                    g.neighbors(u)
                        .map(|(v, w)| (v, EdgeState::point(w)))
                        .collect()
                })
                .collect(),
        }
    }
}

impl EngineStore for HashStore {
    type Row<'a>
        = &'a FxHashMap<u32, EdgeState>
    where
        Self: 'a;

    #[inline]
    fn row(&self, c: u32) -> &FxHashMap<u32, EdgeState> {
        &self.maps[c as usize]
    }

    /// The PR-1 serial apply (the critical section this baseline exists
    /// to measure): per union in ascending-leader order, patch non-merging
    /// targets, install the union map under the leader, retire the
    /// partner.
    fn apply_round(
        &mut self,
        _pool: &Pool,
        unions: &[UnionRow],
        partner_of: impl Fn(u32) -> u32 + Sync,
        patch_target: impl Fn(u32) -> bool + Sync,
    ) {
        for (l, map) in unions {
            let p = partner_of(*l);
            for &(t_id, e) in map {
                if patch_target(t_id) {
                    let tm = &mut self.maps[t_id as usize];
                    tm.remove(&p);
                    tm.insert(*l, e);
                }
            }
            self.maps[*l as usize] = map.iter().copied().collect();
            self.maps[p as usize] = FxHashMap::default();
        }
    }
}

/// Hashmap-backed shared-memory RAC engine (PR-1 baseline; see module
/// docs for why it is retained).
pub struct HashRacEngine {
    driver: RoundDriver<HashStore>,
}

impl HashRacEngine {
    /// Build an engine over a dissimilarity graph (same guards as
    /// [`super::RacEngine::new`]).
    pub fn new(g: &Graph, linkage: Linkage) -> Self {
        assert!(
            linkage.is_reducible(),
            "RAC is exact only for reducible linkages (Theorem 1)"
        );
        if !linkage.supports_sparse() {
            let n = g.n();
            assert!(
                g.m() == n * (n - 1) / 2,
                "{linkage:?} linkage requires a complete graph"
            );
        }
        HashRacEngine {
            driver: RoundDriver::new(HashStore::from_graph(g), g.n(), linkage),
        }
    }

    /// Limit the worker-thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.driver.set_threads(threads);
        self
    }

    /// Run to completion.
    pub fn run(self) -> RacResult {
        let r = self.driver.run(&mut RnnSelector);
        RacResult {
            dendrogram: r.dendrogram,
            metrics: r.metrics,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    #[test]
    fn two_points() {
        let g = Graph::from_edges(2, [(0, 1, 3.5)]);
        let r = HashRacEngine::new(&g, Linkage::Average).run();
        assert_eq!(r.dendrogram.merges().len(), 1);
        assert_eq!(r.dendrogram.merges()[0].weight, 3.5);
    }

    #[test]
    fn matches_flat_engine_bitwise() {
        let g = data::grid1d_graph(250, 9);
        for l in Linkage::SPARSE_REDUCIBLE {
            let hash = HashRacEngine::new(&g, l).with_threads(2).run();
            let flat = super::super::RacEngine::new(&g, l).with_threads(2).run();
            assert_eq!(
                hash.dendrogram.bitwise_merges(),
                flat.dendrogram.bitwise_merges(),
                "{l:?}"
            );
        }
    }
}
