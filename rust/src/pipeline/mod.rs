//! The launcher pipeline: execute a [`RunConfig`] end to end —
//! dataset generation → graph construction → clustering engine — and
//! report results. Shared by the CLI, the examples and the bench harness.

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::approx::ApproxEngine;
use crate::config::{DatasetSpec, EngineSpec, GraphSpec, RunConfig};
use crate::data::{
    adversarial_thm4, gaussian_mixture, grid1d_graph, random_regular_graph, stable_hierarchy,
    topic_docs, Dataset,
};
use crate::dist::{DistApproxEngine, DistConfig, DistRacEngine};
use crate::graph::Graph;
use crate::hac::{naive_hac, nn_chain};
use crate::knn::{complete_graph, epsilon_graph, knn_graph, Backend};
use crate::metrics::RunMetrics;
use crate::rac::{RacEngine, RacResult};
use crate::runtime::{default_artifacts_dir, KernelRuntime};
use crate::trace::{self, TraceSink};
use crate::util::parallel::default_threads;

/// Everything a finished run reports.
pub struct RunOutput {
    pub result: RacResult,
    /// Graph-construction wall time (the paper's "edge loading" analogue;
    /// 15–50% of total in their runs).
    pub t_graph: Duration,
    pub graph_nodes: usize,
    pub graph_edges: usize,
    pub graph_max_degree: usize,
}

/// Generate the configured dataset (vector datasets only).
pub fn build_dataset(cfg: &RunConfig) -> Option<Dataset> {
    match cfg.dataset {
        DatasetSpec::SiftLike {
            n,
            d,
            clusters,
            spread,
            noise_frac,
        } => Some(gaussian_mixture(n, d, clusters, spread, noise_frac, cfg.seed)),
        DatasetSpec::DocsLike { n, d, topics } => Some(topic_docs(n, d, topics, cfg.seed)),
        _ => None,
    }
}

/// Build the dissimilarity graph for a config (generating the dataset if
/// the spec is vector-based; theory specs construct graphs directly).
pub fn build_graph(cfg: &RunConfig) -> Result<Graph> {
    match cfg.dataset {
        DatasetSpec::Grid1d { n } => return Ok(grid1d_graph(n, cfg.seed)),
        DatasetSpec::Adversarial { levels } => return Ok(adversarial_thm4(levels)),
        DatasetSpec::Stable { depth, base } => {
            return Ok(stable_hierarchy(depth, base, cfg.seed))
        }
        DatasetSpec::RandomRegular { n, degree } => {
            return Ok(random_regular_graph(n, degree, cfg.seed))
        }
        _ => {}
    }
    let ds = build_dataset(cfg).expect("vector dataset");
    match cfg.graph {
        GraphSpec::Knn { k, xla } => {
            if xla {
                let rt = KernelRuntime::open(default_artifacts_dir())
                    .context("opening AOT artifacts (run `make artifacts`)")?;
                knn_graph(&ds, k, Backend::Xla, Some(&rt))
            } else {
                knn_graph(&ds, k, Backend::Native, None)
            }
        }
        GraphSpec::Epsilon { eps } => Ok(epsilon_graph(&ds, eps)),
        GraphSpec::Complete => Ok(complete_graph(&ds)),
    }
}

/// Run the configured engine over a graph (untraced).
pub fn run_engine(cfg: &RunConfig, g: &Graph) -> Result<RacResult> {
    run_engine_traced(cfg, g, &TraceSink::disabled())
}

/// Run the configured engine over a graph, streaming structured trace
/// events into `sink` (a disabled sink records nothing and costs one
/// branch per emission site — see [`crate::trace`]). The sequential
/// baselines (`naive_hac`, `nn_chain`) have no round structure and are
/// not traced.
pub fn run_engine_traced(cfg: &RunConfig, g: &Graph, sink: &TraceSink) -> Result<RacResult> {
    // The config parser already enforces this; hand-built configs get the
    // same message instead of silently ignoring the exec block.
    if cfg.exec.is_some()
        && !matches!(
            cfg.engine,
            EngineSpec::DistRac { .. } | EngineSpec::DistApprox { .. }
        )
    {
        bail!("exec options require a distributed engine (dist_rac or dist_approx)");
    }
    // Pin the row-scan kernels to the scalar fallback for the duration of
    // this run only — the guard restores the entry dispatch (including an
    // environment-level RAC_FORCE_SCALAR pin) on every exit path, so a
    // process that runs multiple configs never inherits a stale pin.
    let _scalar_pin = cfg
        .force_scalar
        .then(crate::store::scan::KernelPin::scalar);
    match cfg.engine {
        EngineSpec::NaiveHac => {
            let t = Instant::now();
            let dendrogram = naive_hac(g, cfg.linkage);
            Ok(RacResult {
                dendrogram,
                metrics: RunMetrics {
                    rounds: vec![],
                    total_time: t.elapsed(),
                    ..Default::default()
                },
            })
        }
        EngineSpec::NnChain => {
            if !cfg.linkage.is_reducible() {
                bail!("nn_chain requires a reducible linkage");
            }
            let t = Instant::now();
            let dendrogram = nn_chain(g, cfg.linkage);
            Ok(RacResult {
                dendrogram,
                metrics: RunMetrics {
                    rounds: vec![],
                    total_time: t.elapsed(),
                    ..Default::default()
                },
            })
        }
        EngineSpec::Rac { threads } => {
            let threads = if threads == 0 {
                default_threads()
            } else {
                threads
            };
            Ok(RacEngine::new(g, cfg.linkage)
                .with_threads(threads)
                .with_trace(sink)
                .run())
        }
        EngineSpec::DistRac { machines, cpus } => {
            let mut eng = DistRacEngine::new(g, cfg.linkage, DistConfig::new(machines, cpus))
                .with_trace(sink);
            if let Some(opts) = cfg.exec.clone() {
                eng = eng.with_exec(opts);
            }
            Ok(eng.run())
        }
        EngineSpec::Approx { epsilon, threads } => {
            let threads = if threads == 0 {
                default_threads()
            } else {
                threads
            };
            let r = ApproxEngine::new(g, cfg.linkage, epsilon)
                .with_threads(threads)
                .with_trace(sink)
                .run();
            // The per-merge quality trace stays engine-side; the pipeline
            // reports the common dendrogram + metrics shape.
            Ok(RacResult {
                dendrogram: r.dendrogram,
                metrics: r.metrics,
            })
        }
        EngineSpec::DistApprox {
            machines,
            cpus,
            epsilon,
            sync,
        } => {
            let mut eng =
                DistApproxEngine::new(g, cfg.linkage, DistConfig::new(machines, cpus), epsilon)
                    .with_sync_mode(sync)
                    .with_trace(sink);
            if let Some(opts) = cfg.exec.clone() {
                eng = eng.with_exec(opts);
            }
            let r = eng.run();
            Ok(RacResult {
                dendrogram: r.dendrogram,
                metrics: r.metrics,
            })
        }
    }
}

/// Full pipeline: graph then engine, with construction timing. When the
/// config's `[output]` section asks for them, the structured trace and
/// the metrics JSON are written before returning.
pub fn run(cfg: &RunConfig) -> Result<RunOutput> {
    let t = Instant::now();
    let g = build_graph(cfg)?;
    let t_graph = t.elapsed();
    let sink = if cfg.output.trace_path.is_some() {
        TraceSink::enabled()
    } else {
        TraceSink::disabled()
    };
    let result = run_engine_traced(cfg, &g, &sink)?;
    write_outputs(cfg, &result, &sink)?;
    Ok(RunOutput {
        result,
        t_graph,
        graph_nodes: g.n(),
        graph_edges: g.m(),
        graph_max_degree: g.max_degree(),
    })
}

/// Write the `[output]` artifacts: the collected trace (in the
/// configured format), the run's `RunMetrics` JSON, and the binary
/// dendrogram (`rac query`'s input).
pub fn write_outputs(cfg: &RunConfig, result: &RacResult, sink: &TraceSink) -> Result<()> {
    if let Some(path) = &cfg.output.trace_path {
        let events = sink.take();
        let text = trace::write(&events, cfg.output.trace_format);
        std::fs::write(path, text).with_context(|| format!("writing trace to {path:?}"))?;
    }
    if let Some(path) = &cfg.output.metrics_out {
        let mut text = result.metrics.to_json().to_string();
        text.push('\n');
        std::fs::write(path, text).with_context(|| format!("writing metrics to {path:?}"))?;
    }
    if let Some(path) = &cfg.output.dendrogram_path {
        crate::serve::codec::write_file(&result.dendrogram, path)
            .with_context(|| format!("writing dendrogram to {path:?}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn cfg(text: &str) -> RunConfig {
        RunConfig::from_toml_str(text).unwrap()
    }

    #[test]
    fn grid1d_pipeline_end_to_end() {
        let out = run(&cfg(
            "[dataset]\ntype = \"grid1d\"\nn = 500\n[cluster]\nlinkage = \"single\"\n[engine]\ntype = \"rac\"\n",
        ))
        .unwrap();
        assert_eq!(out.result.dendrogram.merges().len(), 499);
        assert_eq!(out.graph_nodes, 500);
        assert_eq!(out.graph_edges, 499);
    }

    #[test]
    fn sift_like_native_knn_pipeline() {
        let out = run(&cfg(
            "[dataset]\ntype = \"sift_like\"\nn = 200\nd = 16\nclusters = 5\n\
             [graph]\ntype = \"knn\"\nk = 8\n[engine]\ntype = \"dist_rac\"\nmachines = 3\ncpus = 2\n",
        ))
        .unwrap();
        // kNN graphs can be disconnected; every component fully merges.
        let d = &out.result.dendrogram;
        d.validate().unwrap();
        assert!(d.merges().len() >= 190, "{} merges", d.merges().len());
        assert!(out.graph_max_degree >= 8);
    }

    #[test]
    fn engines_agree_through_pipeline() {
        let base = "[dataset]\ntype = \"docs_like\"\nn = 120\nd = 32\ntopics = 6\n\
                    [graph]\ntype = \"knn\"\nk = 6\n";
        let mk = |engine: &str| {
            let text = format!("{base}[engine]\ntype = \"{engine}\"\n");
            run(&cfg(&text)).unwrap().result.dendrogram
        };
        let hac = mk("naive_hac");
        let chain = mk("nn_chain");
        let rac = mk("rac");
        let dist = mk("dist_rac");
        assert!(hac.same_clustering(&chain, 1e-9));
        assert!(hac.same_clustering(&rac, 1e-9));
        assert!(hac.same_clustering(&dist, 1e-9));
    }

    #[test]
    fn approx_engine_through_pipeline() {
        let base = "[dataset]\ntype = \"grid1d\"\nn = 400\n[cluster]\nlinkage = \"average\"\n";
        let exact = run(&cfg(&format!("{base}[engine]\ntype = \"rac\"\n")))
            .unwrap()
            .result;
        // ε = 0 through the config path is still bitwise-exact RAC.
        let zero = run(&cfg(&format!(
            "{base}[engine]\ntype = \"approx\"\nepsilon = 0\n"
        )))
        .unwrap()
        .result;
        assert_eq!(
            exact.dendrogram.bitwise_merges(),
            zero.dendrogram.bitwise_merges()
        );
        // ε > 0 still fully clusters the component and reports rounds.
        let relaxed = run(&cfg(&format!(
            "{base}[engine]\ntype = \"approx\"\nepsilon = 0.5\n"
        )))
        .unwrap()
        .result;
        assert_eq!(relaxed.dendrogram.merges().len(), 399);
        assert!(relaxed.metrics.merge_rounds() > 0);
    }

    #[test]
    fn dist_approx_engine_through_pipeline() {
        let base = "[dataset]\ntype = \"grid1d\"\nn = 300\n[cluster]\nlinkage = \"average\"\n";
        // ε = 0 through the config path degenerates to dist_rac (hence
        // exact RAC), bitwise.
        let exact = run(&cfg(&format!(
            "{base}[engine]\ntype = \"dist_rac\"\nmachines = 3\ncpus = 2\n"
        )))
        .unwrap()
        .result;
        let zero = run(&cfg(&format!(
            "{base}[engine]\ntype = \"dist_approx\"\nmachines = 3\ncpus = 2\nepsilon = 0\n"
        )))
        .unwrap()
        .result;
        assert_eq!(
            exact.dendrogram.bitwise_merges(),
            zero.dendrogram.bitwise_merges()
        );
        // ε > 0 sharded equals ε > 0 shared-memory, bitwise, and reports
        // network traffic.
        let relaxed_shared = run(&cfg(&format!(
            "{base}[engine]\ntype = \"approx\"\nepsilon = 0.5\n"
        )))
        .unwrap()
        .result;
        let relaxed_dist = run(&cfg(&format!(
            "{base}[engine]\ntype = \"dist_approx\"\nmachines = 5\ncpus = 1\nepsilon = 0.5\n"
        )))
        .unwrap()
        .result;
        assert_eq!(
            relaxed_shared.dendrogram.bitwise_merges(),
            relaxed_dist.dendrogram.bitwise_merges()
        );
        assert!(relaxed_dist.metrics.total_net_messages() > 0);
    }

    #[test]
    fn batched_dist_approx_through_pipeline() {
        let base = "[dataset]\ntype = \"grid1d\"\nn = 300\n[cluster]\nlinkage = \"average\"\n";
        // ε = 0 batched builds the exact merge tree (distinct weights on
        // a random grid), though rounds group differently — compare
        // dendrogram-wise, not bitwise (engine docs).
        let exact = run(&cfg(&format!("{base}[engine]\ntype = \"rac\"\n")))
            .unwrap()
            .result;
        let zero = run(&cfg(&format!(
            "{base}[engine]\ntype = \"dist_approx\"\nmachines = 3\ncpus = 2\nepsilon = 0\n\
             sync_mode = \"batched\"\nvshards = 8\n"
        )))
        .unwrap()
        .result;
        assert!(exact.dendrogram.same_clustering(&zero.dendrogram, 1e-9));
        // ε > 0 batched fully clusters and needs fewer syncs than rounds.
        let relaxed = run(&cfg(&format!(
            "{base}[engine]\ntype = \"dist_approx\"\nmachines = 3\ncpus = 2\nepsilon = 0.5\n\
             sync_mode = \"batched\"\nvshards = 8\n"
        )))
        .unwrap()
        .result;
        assert_eq!(relaxed.dendrogram.merges().len(), 299);
        assert!(relaxed.metrics.total_sync_points() < relaxed.metrics.rounds.len());
    }

    #[test]
    fn executed_mode_through_pipeline_matches_simulated() {
        let base = "[dataset]\ntype = \"grid1d\"\nn = 200\n[cluster]\nlinkage = \"average\"\n\
                    [engine]\ntype = \"dist_rac\"\nmachines = 3\ncpus = 2\n";
        let sim = run(&cfg(base)).unwrap().result;
        let exec = run(&cfg(&format!("{base}exec_mode = \"executed\"\n")))
            .unwrap()
            .result;
        assert_eq!(
            sim.dendrogram.bitwise_merges(),
            exec.dendrogram.bitwise_merges()
        );
        // Each mode reports only the clock it has.
        assert!(sim.metrics.total_exec_time().is_zero());
        assert!(!sim.metrics.total_sim_time().is_zero());
        assert!(!exec.metrics.total_exec_time().is_zero());
        assert!(exec.metrics.total_sim_time().is_zero());
    }

    #[test]
    fn output_section_writes_trace_and_metrics_files() {
        let dir = std::env::temp_dir().join(format!("racout-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let trace_path = dir.join("run.trace.jsonl");
        let metrics_path = dir.join("metrics.json");
        let out = run(&cfg(&format!(
            "[dataset]\ntype = \"grid1d\"\nn = 120\n[cluster]\nlinkage = \"average\"\n\
             [engine]\ntype = \"dist_rac\"\nmachines = 3\ncpus = 2\n\
             [output]\ntrace_path = {trace_path:?}\nmetrics_out = {metrics_path:?}\n"
        )))
        .unwrap();
        // The trace parses and its totals match the run's metrics.
        let events = crate::trace::parse_any(&std::fs::read_to_string(&trace_path).unwrap())
            .unwrap();
        crate::trace::analyze::validate_events(&events).unwrap();
        let report = crate::trace::analyze::analyze(&events);
        assert_eq!(report.net_bytes, out.result.metrics.total_net_bytes());
        assert_eq!(report.sync_points, out.result.metrics.total_sync_points());
        // The metrics file parses back through our own reader (satellite
        // contract: machine-readable RunMetrics on disk).
        let js = crate::util::json::Json::parse(
            &std::fs::read_to_string(&metrics_path).unwrap(),
        )
        .unwrap();
        assert_eq!(
            js.get("total_merges").and_then(|v| v.as_usize()),
            Some(out.result.metrics.total_merges())
        );
        assert_eq!(
            js.get("total_net_bytes").and_then(|v| v.as_usize()),
            Some(out.result.metrics.total_net_bytes())
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn output_section_writes_dendrogram_file() {
        let dir = std::env::temp_dir().join(format!("racdend-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let dend_path = dir.join("run.dend");
        let out = run(&cfg(&format!(
            "[dataset]\ntype = \"grid1d\"\nn = 150\n[cluster]\nlinkage = \"average\"\n\
             [engine]\ntype = \"rac\"\n[output]\ndendrogram_path = {dend_path:?}\n"
        )))
        .unwrap();
        // The file round-trips bit-exact and serves the same cuts.
        let back = crate::serve::codec::read_file(&dend_path).unwrap();
        assert_eq!(
            back.bitwise_merges(),
            out.result.dendrogram.bitwise_merges()
        );
        let idx = crate::serve::ServeIndex::build(&back).unwrap();
        assert_eq!(
            idx.cut_threshold(1.5),
            out.result.dendrogram.cut_threshold(1.5)
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ward_requires_complete_graph_via_config() {
        let bad = cfg(
            "[dataset]\ntype = \"sift_like\"\nn = 50\nd = 8\n[graph]\ntype = \"knn\"\nk = 5\n\
             [cluster]\nlinkage = \"ward\"\n[engine]\ntype = \"rac\"\n",
        );
        assert!(std::panic::catch_unwind(|| run(&bad)).is_err());
        let good = cfg(
            "[dataset]\ntype = \"sift_like\"\nn = 50\nd = 8\n[graph]\ntype = \"complete\"\n\
             [cluster]\nlinkage = \"ward\"\n[engine]\ntype = \"rac\"\n",
        );
        assert_eq!(run(&good).unwrap().result.dendrogram.merges().len(), 49);
    }
}
