//! Scaling study (paper Fig 3 in miniature): how RAC's runtime responds to
//! more machines and more CPUs per machine.
//!
//! ```bash
//! cargo run --offline --release --example scaling_study
//! ```
//!
//! The full parameter sweep that regenerates Fig 3's four panels lives in
//! `cargo bench --bench fig3_scaling`; this example is the quick
//! human-readable version.

use std::time::Instant;

use rac_hac::data::gaussian_mixture;
use rac_hac::dist::{DistConfig, DistRacEngine};
use rac_hac::knn::{knn_graph, Backend};
use rac_hac::linkage::Linkage;

fn main() -> anyhow::Result<()> {
    let n = 6000;
    println!("dataset: SIFT-like n={n} d=64, kNN k=12, complete linkage\n");
    let ds = gaussian_mixture(n, 64, 48, 0.8, 0.02, 7);
    let g = knn_graph(&ds, 12, Backend::Native, None)?;
    println!("graph: {} edges, max degree {}\n", g.m(), g.max_degree());

    let run = |machines: usize, cpus: usize| {
        let t = Instant::now();
        let r = DistRacEngine::new(
            &g,
            Linkage::Complete,
            DistConfig::new(machines, cpus),
        )
        .run();
        (t.elapsed(), r)
    };

    println!("-- machines sweep (1 cpu each; paper Fig 3a/3b) --");
    let (base_t, base_r) = run(1, 1);
    println!(
        "  1 machine : {base_t:>9.2?}  (1.00x)  [{} rounds, {} net msgs]",
        base_r.metrics.merge_rounds(),
        base_r.metrics.total_net_messages()
    );
    for machines in [2, 4, 8] {
        let (t, r) = run(machines, 1);
        println!(
            "  {machines} machines: {t:>9.2?}  ({:.2}x)  [{} rounds, {} net msgs]",
            base_t.as_secs_f64() / t.as_secs_f64(),
            r.metrics.merge_rounds(),
            r.metrics.total_net_messages()
        );
        assert!(r.dendrogram.same_clustering(&base_r.dendrogram, 1e-9));
    }

    println!("\n-- CPUs sweep (4 machines; paper Fig 3c) --");
    let (base_t, _) = run(4, 1);
    println!("  1 cpu/machine : {base_t:>9.2?}  (1.00x)");
    for cpus in [2, 4] {
        let (t, _) = run(4, cpus);
        println!(
            "  {cpus} cpus/machine: {t:>9.2?}  ({:.2}x)",
            base_t.as_secs_f64() / t.as_secs_f64()
        );
    }

    println!("\n(identical dendrograms across all topologies — Theorem 1 in action)");
    Ok(())
}
