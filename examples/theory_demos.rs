//! Theory demonstrations: the paper's §4 results, executed.
//!
//! * **Theorem 4** — an adversarial 1-d input where the dendrogram has
//!   height `log n` but RAC needs ~`n` rounds (parallelism collapses).
//! * **Theorem 5** — on a stable cluster tree, RAC finishes in exactly
//!   `height` rounds (perfect parallelism).
//! * **§4.2.2** — the 1-d grid merges ≥ 1/3 of clusters per round under
//!   single linkage (Theorem 6's α).
//! * **Centroid linkage** — outside Theorem 1's hypothesis (not
//!   reducible): RAC's output can diverge from HAC's.
//!
//! ```bash
//! cargo run --offline --release --example theory_demos
//! ```

use rac_hac::data::{adversarial_thm4, grid1d_graph, stable_hierarchy};
use rac_hac::hac::naive_hac;
use rac_hac::linkage::Linkage;
use rac_hac::rac::RacEngine;

fn main() {
    // ---- Theorem 4: Ω(n) rounds at height log n ------------------------
    println!("== Theorem 4: adversarial input (average linkage) ==");
    println!("{:>6} {:>8} {:>8} {:>14}", "n", "height", "rounds", "rounds/height");
    for levels in [4u32, 6, 8] {
        let g = adversarial_thm4(levels);
        let n = g.n();
        let r = RacEngine::new(&g, Linkage::Average).run();
        let height = r.dendrogram.height();
        let rounds = r.metrics.merge_rounds();
        println!("{n:>6} {height:>8} {rounds:>8} {:>14.1}", rounds as f64 / height as f64);
        assert_eq!(height, levels as usize, "HAC tree is the complete binary tree");
        assert!(rounds >= n / 2, "rounds must grow linearly in n");
    }
    println!("  -> rounds grow ~n while height stays log n: no parallelism.\n");

    // ---- Theorem 5: stable tree => rounds == height --------------------
    println!("== Theorem 5: stable hierarchy (average linkage) ==");
    println!("{:>6} {:>8} {:>8}", "n", "height", "rounds");
    for depth in [4u32, 6, 8, 10] {
        let g = stable_hierarchy(depth, 4.0, depth as u64);
        let r = RacEngine::new(&g, Linkage::Average).run();
        let rounds = r.metrics.merge_rounds();
        println!("{:>6} {:>8} {:>8}", g.n(), depth, rounds);
        assert_eq!(rounds, depth as usize, "stability => rounds == height");
    }
    println!("  -> every level of the tree merges in one parallel round.\n");

    // ---- §4.2.2: 1-d grid alpha ----------------------------------------
    println!("== 1-d grid: per-round merge fraction (single linkage) ==");
    let g = grid1d_graph(20_000, 3);
    let r = RacEngine::new(&g, Linkage::Single).run();
    // Round 1 has fresh uniformly-random gap ranks: the paper's exact
    // computation gives alpha = 1/3 (local-minimum density). Later rounds
    // are conditioned on survival (not local minima), which biases alpha
    // down to ~1/4 — still the constant lower bound Theorem 6 needs.
    let first = r.metrics.rounds[0].alpha();
    let alphas: Vec<f64> = r
        .metrics
        .rounds
        .iter()
        .filter(|rm| rm.clusters > 100)
        .map(|rm| rm.alpha())
        .collect();
    let mean = alphas.iter().sum::<f64>() / alphas.len() as f64;
    println!(
        "  rounds: {} (n = 20000); round-1 alpha {first:.3} (theory: 1/3); \
         mean alpha {mean:.3} (constant > 0)",
        r.metrics.merge_rounds()
    );
    assert!((first - 1.0 / 3.0).abs() < 0.02, "round-1 alpha should be ~1/3");
    assert!(mean > 0.2, "later rounds must keep a constant merge fraction");
    assert!(
        r.metrics.merge_rounds() < 3 * (20_000f64).log2() as usize,
        "round count must be O(log n)"
    );
    println!("  -> O(log n) rounds via constant merge fraction.\n");

    // ---- Centroid: Theorem 1's hypothesis is necessary -----------------
    println!("== Centroid linkage (NOT reducible): RAC may diverge from HAC ==");
    let mut diverged = 0;
    for seed in 0..20 {
        let g = stable_hierarchy(4, 3.0, 1000 + seed);
        let hac = naive_hac(&g, Linkage::Centroid);
        let rac = RacEngine::new_unchecked(&g, Linkage::Centroid).run();
        if !hac.same_clustering(&rac.dendrogram, 1e-9) {
            diverged += 1;
        }
    }
    println!("  {diverged}/20 random instances diverged (reducible linkages: always 0)");
    println!("\ntheory_demos OK");
}
