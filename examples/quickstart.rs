//! Quickstart: cluster a handful of 2-d points with exact HAC via RAC.
//!
//! ```bash
//! cargo run --offline --release --example quickstart
//! ```

use rac_hac::data::{Dataset, Metric};
use rac_hac::knn::complete_graph;
use rac_hac::linkage::Linkage;
use rac_hac::rac::RacEngine;

fn main() {
    // Three obvious groups of 2-d points.
    #[rustfmt::skip]
    let points: &[[f32; 2]] = &[
        [0.0, 0.0], [0.1, 0.2], [0.2, 0.1],      // group A
        [5.0, 5.0], [5.1, 5.2], [4.9, 5.1],      // group B
        [10.0, 0.0], [10.2, 0.1], [9.9, -0.1],   // group C
    ];
    let ds = Dataset {
        n: points.len(),
        d: 2,
        metric: Metric::L2,
        rows: points.iter().flatten().copied().collect(),
    };

    // Complete dissimilarity graph -> RAC with average linkage.
    let g = complete_graph(&ds);
    let result = RacEngine::new(&g, Linkage::Average).run();

    println!("merge list (order within a round is by leader id):");
    for m in result.dendrogram.merges() {
        println!("  {:>2} + {:>2}  at dissimilarity {:.3}", m.a, m.b, m.weight);
    }
    println!(
        "\n{} merges in {} parallel rounds (sequential HAC would need {} steps)",
        result.metrics.total_merges(),
        result.metrics.merge_rounds(),
        result.metrics.total_merges(),
    );

    // Cut the hierarchy into 3 flat clusters.
    let labels = result.dendrogram.cut_k(3);
    println!("\nflat cut at k=3: {labels:?}");
    assert_eq!(labels[0], labels[1]);
    assert_eq!(labels[3], labels[4]);
    assert_ne!(labels[0], labels[3]);
    println!("quickstart OK");
}
