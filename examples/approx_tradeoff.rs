//! Quickstart for the (1+ε)-approximate engine: when exact RAC's rounds
//! collapse, a small ε restores parallelism at a provably bounded cost.
//!
//! ```bash
//! cargo run --offline --release --example approx_tradeoff
//! ```

use rac_hac::approx::{quality, ApproxEngine};
use rac_hac::data::adversarial_thm4;
use rac_hac::linkage::Linkage;
use rac_hac::rac::RacEngine;

fn main() {
    // The paper's Theorem-4 adversarial instance: sequential HAC builds a
    // balanced tree, but only ONE reciprocal-nearest-neighbor pair exists
    // per round, so exact RAC degenerates to one merge per round.
    let g = adversarial_thm4(8); // n = 256, complete graph
    let exact = RacEngine::new(&g, Linkage::Average).run();
    println!(
        "exact RAC:   {} merges in {:>3} rounds",
        exact.metrics.total_merges(),
        exact.metrics.merge_rounds()
    );

    // Relax the merge rule: a cluster may merge with any neighbor whose
    // linkage is within (1+ε) of the best merge visible to either
    // endpoint (TeraHAC's good-merge criterion). ε = 0 is bitwise-exact
    // RAC; tiny ε already collapses the round count here.
    for epsilon in [0.0, 0.01, 0.1, 1.0] {
        let approx = ApproxEngine::new(&g, Linkage::Average, epsilon).run();

        // Quality instruments: the worst per-merge goodness ratio (the
        // engine's contract keeps it ≤ 1+ε) and the adjusted Rand index
        // of an 8-cluster flat cut against the exact dendrogram.
        let ratio = quality::merge_quality_ratio(&approx.bounds);
        assert!(ratio <= 1.0 + epsilon + 1e-12);
        let ari = quality::adjusted_rand_index(
            &exact.dendrogram.cut_k(8),
            &approx.dendrogram.cut_k(8),
        );
        println!(
            "eps = {epsilon:<4}: {} merges in {:>3} rounds  (worst ratio {ratio:.6}, ARI@8 {ari:.3})",
            approx.metrics.total_merges(),
            approx.metrics.merge_rounds(),
        );

        if epsilon == 0.0 {
            // The correctness anchor: ε = 0 is not "close" — it is the
            // exact engine, bit for bit.
            assert_eq!(
                exact.dendrogram.bitwise_merges(),
                approx.dendrogram.bitwise_merges()
            );
        }
    }
    println!("\napprox_tradeoff example OK");
}
