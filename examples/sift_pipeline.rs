//! End-to-end driver (DESIGN.md, deliverable (b)): the full three-layer
//! stack on a real small workload.
//!
//! 1. Generate a SIFT-like dataset (Gaussian mixture, 128-d, l2) with
//!    ground-truth component labels — the DESIGN.md §1 substitute for
//!    SIFT200K at a laptop-feasible scale.
//! 2. Build its kNN dissimilarity graph by streaming tiles through the
//!    **AOT-compiled Pallas kernels on the PJRT CPU client** (Layer 1+2;
//!    add `--native` to use the pure-Rust fallback instead).
//! 3. Cluster with the **distributed RAC engine** (Layer 3): sharded
//!    state, batched cross-machine messages, parallel reciprocal-NN
//!    merges.
//! 4. Report the paper's quantities (merges, rounds, α, β, network) and
//!    score a flat cut against the generating mixture (purity) to show
//!    the hierarchy is not just fast but right.
//!
//! ```bash
//! make artifacts   # once
//! cargo run --offline --release --example sift_pipeline            # XLA path
//! cargo run --offline --release --example sift_pipeline -- --native
//! cargo run --offline --release --example sift_pipeline -- --n 20000
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use rac_hac::data::gaussian_mixture_labeled;
use rac_hac::dist::{DistConfig, DistRacEngine};
use rac_hac::knn::{knn_graph, Backend};
use rac_hac::linkage::Linkage;
use rac_hac::runtime::{default_artifacts_dir, KernelRuntime};

/// Purity of predicted labels vs ground truth over non-noise points: for
/// each predicted cluster take its majority true label; purity = fraction
/// correctly covered. `noise_label` points are excluded from scoring —
/// outliers merge LAST in any agglomerative hierarchy, so a weight-ranked
/// cut peels them off as singletons before separating real components
/// (correct HAC behaviour, not an error; the cut budgets one extra
/// cluster per outlier).
fn purity(pred: &[u32], truth: &[u32], noise_label: u32) -> f64 {
    use std::collections::HashMap;
    let mut by_cluster: HashMap<u32, HashMap<u32, usize>> = HashMap::new();
    let mut kept = 0usize;
    for (&p, &t) in pred.iter().zip(truth) {
        if t != noise_label {
            *by_cluster.entry(p).or_default().entry(t).or_default() += 1;
            kept += 1;
        }
    }
    let correct: usize = by_cluster
        .values()
        .map(|counts| counts.values().copied().max().unwrap_or(0))
        .sum();
    correct as f64 / kept as f64
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let native = args.iter().any(|a| a == "--native");
    let n: usize = args
        .iter()
        .position(|a| a == "--n")
        .and_then(|i| args.get(i + 1))
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(8000);
    let (d, clusters, k) = (128usize, 64usize, 16usize);
    let (machines, cpus) = (8usize, 2usize);

    println!("== end-to-end: SIFT-like n={n} d={d} ({clusters} true components) ==");

    // 1. Dataset.
    let t = Instant::now();
    let (ds, truth) = gaussian_mixture_labeled(n, d, clusters, 0.8, 0.02, 42);
    println!("dataset generated in {:.2?}", t.elapsed());

    // 2. kNN graph via the AOT kernels (or native fallback).
    let t = Instant::now();
    let g = if native {
        println!("graph backend: native (pure Rust)");
        knn_graph(&ds, k, Backend::Native, None)?
    } else {
        let rt = KernelRuntime::open(default_artifacts_dir())?;
        println!(
            "graph backend: XLA/PJRT ({}), AOT variants: {}",
            rt.platform(),
            rt.manifest().variants.len()
        );
        knn_graph(&ds, k, Backend::Xla, Some(&rt))?
    };
    let t_graph = t.elapsed();
    println!(
        "kNN graph (k={k}): {} edges, max degree {}, built in {t_graph:.2?}",
        g.m(),
        g.max_degree()
    );

    // 3. Distributed RAC, complete linkage (the paper's Table 4 linkage).
    let result = DistRacEngine::new(
        &g,
        Linkage::Complete,
        DistConfig::new(machines, cpus),
    )
    .run();
    let m = &result.metrics;
    println!(
        "\nRAC over {machines} machines x {cpus} cpus: {} merges in {} rounds, {:.2?}",
        m.total_merges(),
        m.merge_rounds(),
        m.total_time
    );
    println!(
        "edge-loading share of total: {:.0}% (paper reports 15-50%)",
        100.0 * t_graph.as_secs_f64() / (t_graph.as_secs_f64() + m.total_time.as_secs_f64())
    );
    println!(
        "min alpha {:.3} | mean beta {:.2} | network {} msgs / {:.2} MiB",
        m.min_alpha(),
        m.mean_beta(),
        m.total_net_messages(),
        m.total_net_bytes() as f64 / (1 << 20) as f64
    );
    let peak = m.rounds.iter().map(|r| r.merges).max().unwrap_or(0);
    println!(
        "merge profile: round-1 {} merges, peak {} (Fig 2-style burst), tree height {}",
        m.rounds.first().map(|r| r.merges).unwrap_or(0),
        peak,
        result.dendrogram.height()
    );

    // 4. Quality: flat cut at the true component count.
    // Budget one extra cluster per background-noise outlier (see purity's
    // docs); if the kNN graph is disconnected the cut may exceed the
    // requested count — purity is still well-defined.
    let n_noise = truth.iter().filter(|&&t| t == clusters as u32).count();
    let cut_k = clusters + n_noise;
    let pred = result.dendrogram.cut_k(cut_k);
    let p = purity(&pred, &truth, clusters as u32);
    println!(
        "\nflat cut at k={cut_k} ({clusters} components + {n_noise} outliers): \
         purity vs generating mixture = {p:.3}"
    );
    assert!(
        p > 0.9,
        "purity {p:.3} too low — hierarchy does not recover the mixture"
    );
    println!("sift_pipeline OK");
    Ok(())
}
