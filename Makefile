# Convenience targets. `make artifacts` regenerates the AOT HLO kernel set
# the (feature-gated) XLA runtime executes; the pure-Rust paths never need
# it. `make bench` runs the perf-trajectory smoke bench and writes
# BENCH_hot_paths.json (the per-PR datapoint CI uploads as an artifact).

.PHONY: artifacts build test test-scalar test-differential test-executed test-faults clippy fmt fmt-check bench bench-approx bench-dist bench-recovery bench-serve trace-smoke

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

# The whole suite again with the SIMD row-scan kernels pinned to the
# scalar fallback (store::scan). Everything must pass identically: the
# kernels are bitwise-pinned, so a failure only here means the dispatch
# plumbing (not the math) regressed on the scalar path.
test-scalar:
	RAC_FORCE_SCALAR=1 cargo test -q

# The oracle-vs-engine differential suites as a named target, so CI can
# run them as a distinct step: a failure here means an engine diverged
# from an oracle (hashmap store, naive HAC, per-round engine, pinned wire
# traffic), which reads very differently from a unit failure.
test-differential:
	cargo test -q --test store_equivalence --test approx_quality \
		--test dist_batching --test dist_sharding --test theorem1_exactness

# Executed-mode differential + fault recovery and the hostile-bytes codec
# properties, as a named target: a failure here means real threads +
# channels + checkpoint replay diverged from the simulation (or a decoder
# trusted attacker-controlled bytes), which reads very differently from a
# unit failure.
test-executed:
	cargo test -q --test dist_executed --test codec_adversarial

# The fault-tolerance campaign on its own: multi-fault injection, both
# recovery modes, delta-checkpoint chains, and the hostile-bytes delta
# codec properties. A failure here means a faulted run landed on
# different bits than a clean one — recovery is broken, not a unit.
test-faults:
	cargo test -q --test dist_executed fault
	cargo test -q --test dist_executed recover
	cargo test -q --test dist_executed delta
	cargo test -q --test codec_adversarial delta
	cargo test -q --test codec_adversarial chain

# Format in place; CI enforces the check variant.
fmt:
	cargo fmt --all

fmt-check:
	cargo fmt --all -- --check

# --all-targets lints benches, tests and examples too (the library alone
# leaves most of the harness code unlinted).
clippy:
	cargo clippy --all-targets -- -D warnings

# End-to-end smoke of the observability layer: trace a faulted executed
# fleet in Chrome format (the artifact opens directly in Perfetto /
# chrome://tracing), then fold it with `rac trace-report` — the analyzer
# schema-validates every event before reporting, so a non-zero exit means
# the engines emitted a malformed trace. CI uploads the trace + report.
trace-smoke: build
	mkdir -p target/trace-smoke
	printf '%s\n' \
		'[dataset]' 'type = "grid1d"' 'n = 200' \
		'[cluster]' 'linkage = "average"' \
		'[engine]' 'type = "dist_rac"' 'machines = 3' 'cpus = 2' \
		'exec_mode = "executed"' 'faults = "1:2,0:4"' \
		'recovery_mode = "shard_replay"' 'checkpoint_full_every = 2' \
		'[output]' 'trace_path = "target/trace-smoke/trace.json"' \
		'trace_format = "chrome"' \
		'metrics_out = "target/trace-smoke/metrics.json"' \
		> target/trace-smoke/config.toml
	./target/release/rac run --config target/trace-smoke/config.toml
	./target/release/rac trace-report --trace target/trace-smoke/trace.json
	./target/release/rac trace-report --trace target/trace-smoke/trace.json \
		--json > target/trace-smoke/report.json

bench:
	cargo bench --bench hot_paths -- --json --smoke

bench-approx:
	cargo bench --bench approx_tradeoff -- --json --smoke

bench-dist:
	cargo bench --bench dist_sync -- --json --smoke

bench-recovery:
	cargo bench --bench recovery -- --json --smoke

bench-serve:
	cargo bench --bench serve -- --json --smoke
