# Convenience targets. `make artifacts` regenerates the AOT HLO kernel set
# the (feature-gated) XLA runtime executes; the pure-Rust paths never need
# it. `make bench` runs the perf-trajectory smoke bench and writes
# BENCH_hot_paths.json (the per-PR datapoint CI uploads as an artifact).

.PHONY: artifacts build test clippy fmt fmt-check bench bench-approx

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

# Format in place; CI enforces the check variant.
fmt:
	cargo fmt --all

fmt-check:
	cargo fmt --all -- --check

# --all-targets lints benches, tests and examples too (the library alone
# leaves most of the harness code unlinted).
clippy:
	cargo clippy --all-targets -- -D warnings

bench:
	cargo bench --bench hot_paths -- --json --smoke

bench-approx:
	cargo bench --bench approx_tradeoff -- --json --smoke
