# Convenience targets. `make artifacts` regenerates the AOT HLO kernel set
# the (feature-gated) XLA runtime executes; the pure-Rust paths never need
# it.

.PHONY: artifacts build test clippy

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

clippy:
	cargo clippy -- -D warnings
