# Convenience targets. `make artifacts` regenerates the AOT HLO kernel set
# the (feature-gated) XLA runtime executes; the pure-Rust paths never need
# it. `make bench` runs the perf-trajectory smoke bench and writes
# BENCH_hot_paths.json (the per-PR datapoint CI uploads as an artifact).

.PHONY: artifacts build test clippy bench

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

clippy:
	cargo clippy -- -D warnings

bench:
	cargo bench --bench hot_paths -- --json --smoke
